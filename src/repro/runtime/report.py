"""PlaneReport — the one reporting contract every execution plane honors.

Each plane ends a run with a structured report (``PipelineReport``,
``ServingReport``, ``StreamingReport``, ``AsyncServingReport``).  They
grew independently but share load-bearing surface: a ledger slice (the
run's :class:`~repro.runtime.ledger.PhaseRecord` sequence), totals derived
from it, a human ``summary()``, and a ``constraint_violations`` count.
Tools that walk reports (the benchmark harness, the CLI printers, the
system tests) should depend on this protocol, not on any one plane's
dataclass — new planes then plug in by conforming instead of by being
special-cased.

:class:`PlaneReport` is a runtime-checkable :class:`typing.Protocol`, so
conformance is structural (``isinstance(report, PlaneReport)`` checks the
surface exists) and the existing report dataclasses did not have to be
re-parented.  :class:`LedgerTotals` is the convenience mixin new reports
can inherit to derive every total from the attached ledger slice — the
single-source-of-truth rule the ledger module documents.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

from repro.runtime.ledger import ExecLedger


@runtime_checkable
class PlaneReport(Protocol):
    """Common surface of every plane's run report (structural)."""

    ledger: Optional[ExecLedger]        # this run's phase records

    def summary(self) -> str:           # human-readable multi-line account
        ...

    @property
    def total_time_s(self) -> float:    # Σ sim_time_s over the ledger slice
        ...

    @property
    def total_energy_j(self) -> float:  # Σ energy_j over the ledger slice
        ...

    @property
    def total_switches(self) -> int:    # Σ core switches over the slice
        ...

    @property
    def constraint_violations(self) -> int:   # flagged min_speed fallbacks
        ...


class LedgerTotals:
    """Mixin deriving the PlaneReport totals from ``self.ledger``.

    A report holding a ledger slice gets the totals for free and cannot
    drift from it; a ledger-less report (never ran) totals to zero.
    """

    ledger: Optional[ExecLedger] = None

    @property
    def total_time_s(self) -> float:
        return self.ledger.total_time_s if self.ledger else 0.0

    @property
    def total_energy_j(self) -> float:
        return self.ledger.total_energy_j if self.ledger else 0.0

    @property
    def total_switches(self) -> int:
        return self.ledger.total_switches if self.ledger else 0

    @property
    def total_reissued(self) -> int:
        return self.ledger.total_reissued if self.ledger else 0

    @property
    def constraint_violations(self) -> int:
        if self.ledger is None:
            return 0
        return len(self.ledger.constraint_violations())
