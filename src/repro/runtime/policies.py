"""Switching policies — the paper §VI pivot, as a pluggable interface.

"Switching between the cores can be made static or dynamic": a
:class:`SwitchingPolicy` decides how each parallel phase is planned and
what happens to the plan as measurements arrive.

* :class:`StaticPolicy` — plan once per phase from the believed speed
  profile and never revisit it (the paper's static mode).
* :class:`DynamicPolicy` — the paper's dynamic mode, closed-loop: measured
  per-device walls EWMA-update the believed speeds
  (``HeterogeneityProfile.observe``), plan drift versus the previous
  same-shape phase is charged as core switches (``MBScheduler.rebalance``
  semantics), and a planned-progress checkpoint detects stragglers and
  speculatively re-issues their tail tiles (``speculate`` +
  ``apply_moves``) before execution commits.
* :class:`CostModelPolicy` — seeds tile costs from roofline / HLO cost
  estimates (``launch/roofline`` constants, ``launch/hlo_cost.analyze``)
  instead of raw byte counts: a tile's planning cost is
  ``max(flops / peak_flops, bytes / hbm_bw)``, renormalized to the byte
  work-unit scale so time/energy stay on one axis.

Policies are deliberately stateless about *execution*: they see the task,
the costs, the assignment and the measurement, and talk only to the
scheduler/profile the :class:`repro.runtime.Runtime` owns.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.core.scheduler import Assignment, MBScheduler, TaskSpec


class SwitchingPolicy:
    """Interface: cost seeding, phase planning, post-phase feedback."""

    name = "abstract"
    # where this policy's planning costs come from — stamped onto every
    # PhaseRecord so a ledger reader can tell constant-seeded plans from
    # roofline- or autotune-fed ones ("bytes" = the raw byte estimates)
    cost_source = "bytes"

    # -- cost seeding ---------------------------------------------------
    def tile_costs(self, runtime, task: TaskSpec, tile_costs: np.ndarray,
                   tile_flops: Optional[np.ndarray] = None) -> np.ndarray:
        """Planning costs per tile (default: the byte-flavored estimates)."""
        return tile_costs

    # -- planning -------------------------------------------------------
    def plan(self, runtime, task: TaskSpec, tile_costs: np.ndarray
             ) -> Tuple[Assignment, int, int]:
        """Returns ``(assignment, switches, reissued)`` — planner moves
        charged to this phase (0/0 for a static plan)."""
        raise NotImplementedError

    # -- measurement feedback -------------------------------------------
    def feedback(self, runtime, task: TaskSpec, assignment: Assignment,
                 tile_costs: np.ndarray, measured) -> None:
        """Called once per phase with the :class:`MeasuredPhase`."""


class StaticPolicy(SwitchingPolicy):
    """Plan once per phase; no feedback loop (paper static mode)."""

    name = "static"

    def plan(self, runtime, task, tile_costs):
        return runtime.scheduler.assign_parallel(task, tile_costs), 0, 0

    def feedback(self, runtime, task, assignment, tile_costs, measured):
        return None


class DynamicPolicy(StaticPolicy):
    """Closed-loop dynamic core switching (paper dynamic mode).

    ``checkpoint_frac`` — the planned-progress instant (fraction of the
    planned makespan) at which stragglers are tested; mid-phase (0.5) by
    default, where fast cores under a skewed plan have already finished
    (progress clipped at 1) while a straggler sits visibly below the
    median.  ``straggler_threshold`` — a device lags when its planned
    progress is below ``threshold × median`` (same contract as
    ``MBScheduler.speculate``).
    """

    name = "dynamic"

    def __init__(self, checkpoint_frac: float = 0.5,
                 straggler_threshold: float = 0.7):
        if not 0.0 < checkpoint_frac <= 1.0:
            raise ValueError(f"checkpoint_frac must be in (0, 1]: "
                             f"{checkpoint_frac}")
        self.checkpoint_frac = checkpoint_frac
        self.straggler_threshold = straggler_threshold
        # last owner map per (task family, tile arity): tile ids are
        # positional and recur within a family (mining rounds over one
        # tiled bitmap, serving batches of one bucket), so drift between
        # same-family phases is the paper's dynamic core switching,
        # charged per move — unrelated phases that merely share a tile
        # count are never compared
        self._last_owner: Dict[Tuple[str, int], Dict[int, int]] = {}

    def plan(self, runtime, task, tile_costs):
        sched: MBScheduler = runtime.scheduler
        asg = sched.assign_parallel(task, tile_costs)
        n_tiles = task.n_tiles or 1
        key = (task.family_key, n_tiles)

        # rebalance accounting: EWMA-updated speeds moved tiles since the
        # previous same-family phase -> each move is a core switch
        switches = 0
        prev = self._last_owner.get(key)
        if prev is not None:
            now = asg.owner_of()
            switches = sum(1 for t, d in now.items() if prev.get(t, d) != d)
            sched.switches += switches

        # speculative re-issue at the planned-progress checkpoint
        reissued = 0
        if n_tiles > 1 and asg.makespan > 0:
            t_cp = self.checkpoint_frac * asg.makespan
            load = np.array([tile_costs[ts].sum() if ts else 0.0
                             for ts in asg.tiles_of])
            speeds = runtime.profile.speeds
            progress = np.where(load > 0,
                                np.minimum(1.0, t_cp * speeds
                                           / np.maximum(load, 1e-30)),
                                1.0)
            moves = sched.speculate(asg, progress,
                                    threshold=self.straggler_threshold)
            if moves:
                asg = sched.apply_moves(asg, moves, tile_costs)
                reissued = len(moves)

        self._last_owner[key] = asg.owner_of()
        return asg, switches, reissued

    def feedback(self, runtime, task, assignment, tile_costs, measured):
        """EWMA speed update from measured per-device walls.

        Only measurements that carry ``work_done`` feed the loop — modeled
        busy seconds are ``load / believed_speed`` by construction and
        carry no information about the true rates.
        """
        if measured.work_done is None or measured.busy_s is None:
            return
        busy = np.asarray(measured.busy_s, dtype=np.float64)
        work = np.asarray(measured.work_done, dtype=np.float64)
        for d in range(min(len(busy), runtime.profile.n)):
            if busy[d] > 0 and work[d] > 0:
                runtime.profile.observe(d, float(work[d]), float(busy[d]))


class CostModelPolicy(StaticPolicy):
    """Static planning over roofline-seeded tile costs.

    Tile planning cost = ``max(flops / peak_flops, bytes / hbm_bw)``
    seconds at peak, rescaled so the total equals the byte total (the
    scheduler's speeds are byte-flavored work units per second).  Per-tile
    flops come from the caller's ``tile_flops`` estimate; without one,
    ``flops_per_byte`` (e.g. derived from a compiled module via
    :meth:`from_hlo`) is applied uniformly — which degenerates to the
    byte seeding, exactly as it should when no intensity skew is known.

    Peak/bandwidth default to the datasheet roofline constants
    (``cost_source = "roofline"``); :meth:`from_autotune` replaces them
    with *measured* effective rates from an autotune cache
    (``cost_source = "autotune"`` — the tentpole feedback loop: the
    scheduler plans on what the silicon actually did, not on constants).
    """

    name = "costmodel"
    cost_source = "roofline"

    def __init__(self, peak_flops: Optional[float] = None,
                 hbm_bw: Optional[float] = None,
                 flops_per_byte: float = 0.0):
        from repro.launch.roofline import HBM_BW, PEAK_FLOPS
        self.peak_flops = PEAK_FLOPS if peak_flops is None else peak_flops
        self.hbm_bw = HBM_BW if hbm_bw is None else hbm_bw
        self.flops_per_byte = flops_per_byte

    @classmethod
    def from_hlo(cls, hlo_text: str, **kwargs) -> "CostModelPolicy":
        """Seed the default arithmetic intensity from a compiled module."""
        from repro.launch.hlo_cost import analyze
        cost = analyze(hlo_text)
        fpb = cost.flops / max(cost.traffic_bytes, 1.0)
        return cls(flops_per_byte=fpb, **kwargs)

    @classmethod
    def from_autotune(cls, cache, kernel: str,
                      device: Optional[str] = None,
                      **kwargs) -> "CostModelPolicy":
        """Seed effective peak/bandwidth from measured autotune entries.

        Each cache entry carries the shape it was tuned at and the
        winner's measured wall; the task-intrinsic (flops, bytes) of that
        shape (``launch.tuning.shape_flops_bytes``) turn the wall into an
        achieved flops/s and bytes/s — the median over entries replaces
        the datasheet constants, and the median arithmetic intensity
        seeds ``flops_per_byte``.  Raises ``ValueError`` when the cache
        has no measured entries for this (kernel, device): the caller
        decides whether to fall back to constants, never silently.
        """
        from repro.launch.tuning import shape_flops_bytes
        entries = [e for e in cache.entries_for(kernel, device)
                   if e.get("cost_us", 0) > 0 and e.get("shape")]
        if not entries:
            raise ValueError(
                f"autotune cache has no measured entries for {kernel!r} on "
                f"device {device or 'current'} — cannot seed measured costs")
        peaks, bws, intens = [], [], []
        for e in entries:
            flops, bytes_ = shape_flops_bytes(kernel, tuple(e["shape"]))
            wall_s = float(e["cost_us"]) * 1e-6
            peaks.append(flops / wall_s)
            bws.append(bytes_ / wall_s)
            intens.append(flops / bytes_)
        policy = cls(peak_flops=float(np.median(peaks)),
                     hbm_bw=float(np.median(bws)),
                     flops_per_byte=float(np.median(intens)), **kwargs)
        policy.cost_source = "autotune"
        return policy

    def tile_costs(self, runtime, task, tile_costs, tile_flops=None):
        bytes_ = np.asarray(tile_costs, dtype=np.float64)
        total = float(bytes_.sum())
        if total <= 0:
            return bytes_
        if tile_flops is None:
            flops = bytes_ * self.flops_per_byte
        else:
            flops = np.asarray(tile_flops, dtype=np.float64)
        roofline_s = np.maximum(flops / self.peak_flops,
                                bytes_ / self.hbm_bw)
        rs = float(roofline_s.sum())
        if rs <= 0:
            return bytes_
        # renormalize to the byte work-unit scale: same total work,
        # redistributed by roofline intensity
        return roofline_s * (total / rs)


def autotuned_costmodel(kernel: str, cache=None) -> CostModelPolicy:
    """Costmodel policy seeded from the autotune cache when it can be.

    The planes call this when their config asks for the ``costmodel``
    policy by name with autotuning on: measured entries for *kernel* on
    the current device replace the datasheet constants
    (``cost_source = "autotune"``); a cold/corrupt/other-device cache
    degrades to the roofline-constant policy — autotuning may only make
    planning better-informed, never take a plane down."""
    if cache is None:
        from repro.kernels.autotune.cache import default_cache
        cache = default_cache()
    try:
        return CostModelPolicy.from_autotune(cache, kernel)
    except ValueError:
        return CostModelPolicy()


_POLICIES = {
    "static": StaticPolicy,
    "dynamic": DynamicPolicy,
    "costmodel": CostModelPolicy,
}

POLICY_NAMES = tuple(sorted(_POLICIES))


def resolve_policy(policy: Union[str, SwitchingPolicy, None]
                   ) -> SwitchingPolicy:
    """Name or instance -> instance (None = static)."""
    if policy is None:
        return StaticPolicy()
    if isinstance(policy, SwitchingPolicy):
        return policy
    cls = _POLICIES.get(policy)
    if cls is None:
        raise ValueError(f"unknown switching policy {policy!r} "
                         f"(known: {', '.join(POLICY_NAMES)})")
    return cls()
