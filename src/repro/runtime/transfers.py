"""Device-transfer accounting — the observability half of pipelined rounds.

JAX dispatch is asynchronous: a round of tile kernels costs almost nothing
to *launch*; what serializes a mining round is every host/device boundary
crossing — an ``np.asarray`` on a device value blocks until the whole
dependency chain flushes (one sync), and every ``jnp.asarray`` of host data
is an H2D copy.  The planes therefore route **all** boundary crossings
through a :class:`TransferMeter`, which makes three quantities exact and
ledger-attributable per phase:

* ``h2d_bytes`` — bytes staged host → device (tile uploads, candidate
  slabs on the legacy path, fallback candidate matrices)
* ``d2h_bytes`` — bytes read back device → host (one packed count vector
  per round on the pipelined path; per-tile vectors on the legacy path)
* ``syncs``     — device→host synchronization points (each ``d2h`` is one;
  the pipelined round contract is **exactly one per counting round**)

:class:`repro.runtime.Runtime` snapshots its meter after every phase, so
each :class:`~repro.runtime.ledger.PhaseRecord` carries the transfers that
happened since the previous phase ended — staging between phases (e.g. the
one-time tile upload) lands on the phase that consumes it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TransferStats:
    """A point-in-time (or delta) view of a meter's counters."""

    h2d_bytes: int = 0
    d2h_bytes: int = 0
    syncs: int = 0

    def __sub__(self, other: "TransferStats") -> "TransferStats":
        return TransferStats(self.h2d_bytes - other.h2d_bytes,
                             self.d2h_bytes - other.d2h_bytes,
                             self.syncs - other.syncs)

    def __add__(self, other: "TransferStats") -> "TransferStats":
        return TransferStats(self.h2d_bytes + other.h2d_bytes,
                             self.d2h_bytes + other.d2h_bytes,
                             self.syncs + other.syncs)


class TransferMeter:
    """Counts every host/device boundary crossing routed through it.

    ``h2d``/``d2h`` are drop-in replacements for ``jnp.asarray`` /
    ``np.asarray`` that account bytes (and, for ``d2h``, the sync point).
    Both run under ``jax.transfer_guard("allow")`` so a test can wrap a
    whole mine in ``jax.transfer_guard("disallow")`` and catch any
    *unaccounted* transfer the planes still make.
    """

    def __init__(self) -> None:
        self.h2d_bytes = 0
        self.d2h_bytes = 0
        self.syncs = 0

    # ------------------------------------------------------------------
    def h2d(self, x: Any, dtype=None) -> jnp.ndarray:
        """Stage host data on device, counting the bytes moved.  A value
        that is already device-resident passes through uncounted — call
        sites can route every input here without double-billing."""
        if isinstance(x, jax.Array):
            return x if dtype is None else x.astype(dtype)
        with jax.transfer_guard("allow"):
            out = jnp.asarray(x, dtype=dtype)
        self.h2d_bytes += int(out.nbytes)
        return out

    def d2h(self, x: Any, dtype=None) -> np.ndarray:
        """Read a device value back to host: one sync + its bytes.  Host
        values pass through uncounted (no boundary crossed)."""
        if isinstance(x, np.ndarray) and not isinstance(x, jnp.ndarray):
            return x if dtype is None else np.asarray(x, dtype=dtype)
        with jax.transfer_guard("allow"):
            out = np.asarray(x, dtype=dtype)
        self.d2h_bytes += int(out.nbytes)
        self.syncs += 1
        return out

    def sync(self, n: int = 1) -> None:
        """Record a synchronization that moved no bytes through the meter
        (e.g. an explicit ``block_until_ready``)."""
        self.syncs += n

    # ------------------------------------------------------------------
    def stats(self) -> TransferStats:
        return TransferStats(self.h2d_bytes, self.d2h_bytes, self.syncs)

    def since(self, mark: TransferStats) -> TransferStats:
        return self.stats() - mark


# A process-wide default for callers without a Runtime (reference drivers,
# one-off scripts).  Planes use their Runtime's own meter so concurrent
# planes cannot cross-attribute each other's transfers.
METER = TransferMeter()
