"""Donated-buffer jit helpers — round-persistent slabs without realloc.

``jax.jit(..., donate_argnums=...)`` lets XLA alias a dead input buffer to
an output of the same shape/dtype, so a per-tile count accumulator or a
per-round candidate slab is *updated in place* instead of reallocated.
Donation is a backend capability: TPU and GPU alias; CPU ignores the
donation and warns per call.  :func:`donated_jit` therefore compiles with
donation only where the backend honors it — semantics are identical either
way (donation is purely an allocation optimization), and the CPU CI legs
stay warning-free.

:class:`SlabPool` keeps one device slab per (shape, dtype) bucket across
rounds: levels whose candidate counts land in the same ``m_bucket`` reuse
the same buffer, which together with ``donate_argnums`` removes the
per-round allocate + H2D of the padded candidate matrix.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

_DONATING_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def donation_supported() -> bool:
    """True when the default backend honors input-output buffer aliasing."""
    return jax.default_backend() in _DONATING_BACKENDS


def donated_jit(fn, *, donate_argnums=(), **jit_kwargs):
    """``jax.jit`` that donates only on backends that alias (no CPU spam)."""
    if donation_supported():
        return jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)
    return jax.jit(fn, **jit_kwargs)


# The round accumulator combiner: ``acc`` is dead after the add, so its
# buffer is reused for the running sum on donation-capable backends.  Every
# tile's partial counts fold into the same persistent buffer, and because
# nothing here synchronizes, all tile kernels of a round dispatch eagerly.
donated_add = donated_jit(lambda acc, x: acc + x, donate_argnums=(0,))

# In-place survivor intersection (the Eclat plane's next-level slab): both
# gathered parent slabs are dead after the AND, so the result aliases one.
donated_and = donated_jit(lambda a, b: a & b, donate_argnums=(0, 1))


class SlabPool:
    """Round-persistent device slabs keyed by bucket shape.

    ``take(shape, dtype)`` returns a zeroed slab, reusing (and donating)
    the previous round's buffer when the bucket shape repeats — the common
    case under ``m_bucket`` rounding, where consecutive Apriori levels
    share a padded candidate shape.
    """

    def __init__(self) -> None:
        self._slabs: Dict[Tuple[Tuple[int, ...], str], jnp.ndarray] = {}
        self._zero = donated_jit(lambda s: s * 0, donate_argnums=(0,))

    def take(self, shape: Tuple[int, ...], dtype) -> jnp.ndarray:
        key = (tuple(shape), jnp.dtype(dtype).name)
        slab = self._slabs.pop(key, None)
        if slab is None:
            return jnp.zeros(shape, dtype)
        return self._zero(slab)

    def give(self, slab: jnp.ndarray) -> None:
        """Return a slab to the pool once the round no longer reads it."""
        self._slabs[(tuple(slab.shape), jnp.dtype(slab.dtype).name)] = slab
