"""Shared scheduling runtime: one MBScheduler + PowerModel + phase ledger
behind every execution plane, with pluggable static/dynamic/costmodel
switching policies (paper §VI)."""
from repro.runtime.donation import (SlabPool, donated_add, donated_and,
                                    donated_jit, donation_supported)
from repro.runtime.ledger import ExecLedger, PhaseRecord
from repro.runtime.policies import (POLICY_NAMES, CostModelPolicy,
                                    DynamicPolicy, StaticPolicy,
                                    SwitchingPolicy, autotuned_costmodel,
                                    resolve_policy)
from repro.runtime.report import LedgerTotals, PlaneReport
from repro.runtime.runtime import MeasuredPhase, Runtime, resolve_power
from repro.runtime.transfers import METER, TransferMeter, TransferStats

__all__ = [
    "METER", "POLICY_NAMES", "CostModelPolicy", "DynamicPolicy",
    "ExecLedger", "LedgerTotals", "MeasuredPhase", "PhaseRecord",
    "PlaneReport", "Runtime", "SlabPool", "StaticPolicy", "SwitchingPolicy",
    "TransferMeter", "TransferStats", "autotuned_costmodel", "donated_add",
    "donated_and", "donated_jit", "donation_supported", "resolve_policy",
    "resolve_power",
]
