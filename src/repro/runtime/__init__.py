"""Shared scheduling runtime: one MBScheduler + PowerModel + phase ledger
behind every execution plane, with pluggable static/dynamic/costmodel
switching policies (paper §VI)."""
from repro.runtime.ledger import ExecLedger, PhaseRecord
from repro.runtime.policies import (POLICY_NAMES, CostModelPolicy,
                                    DynamicPolicy, StaticPolicy,
                                    SwitchingPolicy, autotuned_costmodel,
                                    resolve_policy)
from repro.runtime.report import LedgerTotals, PlaneReport
from repro.runtime.runtime import MeasuredPhase, Runtime, resolve_power

__all__ = [
    "POLICY_NAMES", "CostModelPolicy", "DynamicPolicy", "ExecLedger",
    "LedgerTotals", "MeasuredPhase", "PhaseRecord", "PlaneReport",
    "Runtime", "StaticPolicy", "SwitchingPolicy", "autotuned_costmodel",
    "resolve_policy", "resolve_power",
]
