"""Unified phase accounting — the single source of truth for time/energy.

Every phase any plane executes (a serial driver phase, a simulated map
round, a shard_map round, a serving batch) flows through
:meth:`repro.runtime.Runtime.run_phase` / :meth:`run_serial`, which emit
exactly one :class:`PhaseRecord` into an :class:`ExecLedger`.  The plane
reports (``PipelineReport``, ``ServingReport``) hold a ledger slice and
derive their totals from it, so the three planes cannot drift on what a
second or a joule means (PR 3 had to patch a silently-None ``energy_j``
on the sharded path — this module is the structural fix).

Semantics, identical for every plane:

* ``sim_time_s`` — modeled seconds on the work-unit clock: a serial
  phase's ``cost / speed[device]``; a map phase's makespan.
* ``energy_j`` — active watts for busy seconds, idle watts for the tail a
  core waits on the makespan, gated watts for cores that ran nothing, and
  ``switch_joules`` per *migration* — every core switch AND every
  speculative re-issue moves work, so both are priced.
* ``switches`` / ``reissued`` — planner moves (policy rebalancing, shard
  re-plans) plus execution moves (failure re-planning) for this phase
  only; the scheduler keeps its own lifetime counter.
* ``constraint_violated`` — ``assign_serial`` could not satisfy the
  task's ``min_speed`` and fell back to the fastest core (surfaced, never
  silent).
* ``kind`` — ``"serial"`` (one core runs, the rest gate off), ``"map"``
  (tiled across the profile), or ``"shed"`` (the async serving plane's
  SLO governor rejected a request: the triage work is still scheduled on
  one core and priced, so load shedding shows up in the energy/time
  totals like every other phase instead of vanishing).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class PhaseRecord:
    """One scheduled phase: placement, modeled time, measured wall, energy."""

    name: str
    kind: str                     # "serial" | "map" | "shed"
    policy: str = "static"        # switching policy that planned the phase
    cost_source: str = "bytes"    # where planning costs came from:
    #                               bytes | roofline | autotune
    cost: float = 0.0             # work units the scheduler planned for
    sim_time_s: float = 0.0       # serial run time / map makespan (modeled)
    host_time_s: float = 0.0      # measured host wall (0 = not measured)
    energy_j: float = 0.0
    switches: int = 0
    reissued: int = 0
    busy_s: List[float] = field(default_factory=list)
    gated: List[int] = field(default_factory=list)
    device: Optional[int] = None  # serial phases: the core that ran
    n_tiles: int = 0
    tiles_done: List[int] = field(default_factory=list)
    failed_devices: List[int] = field(default_factory=list)
    constraint_violated: bool = False
    # host/device data movement attributed to this phase (metered by the
    # Runtime's TransferMeter; staging between phases lands on the phase
    # that consumes it).  ``syncs`` counts device->host synchronization
    # points — the pipelined round contract is exactly 1 per map round.
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    syncs: int = 0


@dataclass
class ExecLedger:
    """Append-only sequence of phase records with derived totals."""

    phases: List[PhaseRecord] = field(default_factory=list)

    def add(self, rec: PhaseRecord) -> PhaseRecord:
        self.phases.append(rec)
        return rec

    # ------------------------------------------------------------------
    # slicing: one Runtime serves many runs; each run reports its own slice
    # ------------------------------------------------------------------
    def mark(self) -> int:
        return len(self.phases)

    def since(self, mark: int) -> "ExecLedger":
        return ExecLedger(self.phases[mark:])

    def take_since(self, mark: int) -> "ExecLedger":
        """Slice everything since `mark` into a new ledger (a run's report)
        and drop it from the live one — long-lived planes (the serving
        engine, a reused pipeline) would otherwise accumulate records
        without bound across runs."""
        taken = ExecLedger(self.phases[mark:])
        del self.phases[mark:]
        return taken

    def by_kind(self, kind: str) -> List[PhaseRecord]:
        return [p for p in self.phases if p.kind == kind]

    # ------------------------------------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self.phases)

    @property
    def total_time_s(self) -> float:
        return sum(p.sim_time_s for p in self.phases)

    @property
    def total_energy_j(self) -> float:
        return sum(p.energy_j for p in self.phases)

    @property
    def total_switches(self) -> int:
        return sum(p.switches for p in self.phases)

    @property
    def total_reissued(self) -> int:
        return sum(p.reissued for p in self.phases)

    @property
    def total_h2d_bytes(self) -> int:
        return sum(p.h2d_bytes for p in self.phases)

    @property
    def total_d2h_bytes(self) -> int:
        return sum(p.d2h_bytes for p in self.phases)

    @property
    def total_syncs(self) -> int:
        return sum(p.syncs for p in self.phases)

    def constraint_violations(self) -> List[PhaseRecord]:
        return [p for p in self.phases if p.constraint_violated]

    def summary(self) -> str:
        return (f"ExecLedger: {self.n_phases} phases | "
                f"{self.total_time_s:.4f}s, {self.total_energy_j:.1f}J, "
                f"{self.total_switches} switches, "
                f"{self.total_reissued} re-issues, "
                f"{len(self.constraint_violations())} constraint violations | "
                f"{self.total_h2d_bytes}B h2d, {self.total_d2h_bytes}B d2h, "
                f"{self.total_syncs} syncs")
