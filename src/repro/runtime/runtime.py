"""The shared execution substrate all three planes run on.

One :class:`Runtime` owns one ``MBScheduler`` + ``PowerModel`` + phase
ledger and performs assignment, policy feedback and time/energy/switch
accounting **exactly once**, for every phase of every plane:

  ``MarketBasketPipeline``  — simulated map rounds + serial driver phases
  ``RecommendationEngine``  — admission (serial) + batched scoring (map)
  ``ShardedMiner``          — shard_map rounds (pinned assignments) +
                              driver phases routed to rank 0

The plane supplies *execution* (an ``execute(assignment, costs)`` callback
returning a :class:`MeasuredPhase`); the runtime supplies *scheduling*
(via the :class:`~repro.runtime.policies.SwitchingPolicy`) and
*accounting* (one :class:`~repro.runtime.ledger.PhaseRecord` per phase).
Anything the executor does not measure is modeled from the plan: busy
seconds default to ``load / believed_speed`` and the makespan to their
maximum, so simulated, sharded and serving phases share one time axis.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Union

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.power import PowerModel
from repro.core.scheduler import Assignment, MBScheduler, TaskSpec
from repro.runtime.ledger import ExecLedger, PhaseRecord
from repro.runtime.policies import SwitchingPolicy, resolve_policy
from repro.runtime.transfers import TransferMeter


@dataclass
class MeasuredPhase:
    """What an executor observed.  ``None`` fields are modeled by the
    runtime from the assignment and the believed speed profile."""

    result: Any = None
    busy_s: Optional[np.ndarray] = None    # [n] seconds per device
    makespan: Optional[float] = None
    switches: int = 0                      # execution-time owner changes
    reissued: int = 0
    failed_devices: List[int] = field(default_factory=list)
    tiles_done: Optional[List[int]] = None
    work_done: Optional[np.ndarray] = None  # [n] executed work units (feeds
    #                                         DynamicPolicy's EWMA loop)
    wall_s: float = 0.0                    # measured host wall
    # transfers the executor measured *outside* the runtime's meter (e.g.
    # a shard_map barrier counted as one sync); added on top of the meter
    # delta when the phase is recorded
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    syncs: int = 0


def resolve_power(power: Union[str, PowerModel, None],
                  profile: HeterogeneityProfile) -> Optional[PowerModel]:
    """Name, instance or None -> PowerModel instance (or None = unpriced)."""
    if power is None or isinstance(power, PowerModel):
        return power
    if power == "cpu":
        return PowerModel.cpu(profile)
    if power == "tpu_v5e":
        return PowerModel.tpu_v5e(profile.n)
    if power == "none":
        return None
    raise ValueError(f"unknown power model {power!r}")


class Runtime:
    """Scheduler + power + ledger + switching policy, shared per plane."""

    def __init__(self, profile: HeterogeneityProfile,
                 policy: Union[str, SwitchingPolicy, None] = "static",
                 split: str = "lpt",
                 power: Union[str, PowerModel, None] = "cpu",
                 scheduler: Optional[MBScheduler] = None,
                 ledger: Optional[ExecLedger] = None,
                 meter: Optional[TransferMeter] = None):
        self.profile = profile
        self.scheduler = scheduler or MBScheduler(profile, policy=split)
        self.policy = resolve_policy(policy)
        self.power = resolve_power(power, profile)
        self.ledger = ledger if ledger is not None else ExecLedger()
        # per-runtime transfer meter: every phase record absorbs whatever
        # crossed the host/device boundary since the previous phase ended,
        # so inter-phase staging (tile uploads) lands on its consumer
        self.meter = meter if meter is not None else TransferMeter()
        self._transfer_mark = self.meter.stats()

    def _take_transfers(self):
        delta = self.meter.since(self._transfer_mark)
        self._transfer_mark = self.meter.stats()
        return delta

    @property
    def split(self) -> str:
        """Tile-split strategy (lpt | proportional | equal)."""
        return self.scheduler.policy

    # ------------------------------------------------------------------
    # serial phases: one core runs, the rest gate off (paper function 3)
    # ------------------------------------------------------------------
    def run_serial(self, name: str, cost: float,
                   fn: Optional[Callable[[], Any]] = None,
                   device: Optional[int] = None,
                   min_speed: float = 0.0,
                   kind: str = "serial"):
        """Model (and optionally execute) a single-threaded phase.

        ``fn`` runs on the host and its wall time is recorded; ``device``
        pins the core (the sharded plane routes driver phases to rank 0).
        ``kind`` stamps the ledger record — serial-shaped work that is not
        a plain driver phase (the async serving plane's SLO sheds) stays
        distinguishable without a second accounting path.  Returns
        ``(fn result or None, PhaseRecord)``.
        """
        task = TaskSpec(name, cost, parallel=False, min_speed=min_speed)
        asg = self.scheduler.assign_serial(task, device=device)
        dev = asg.serial_device
        sim_t = float(asg.est_finish[dev])
        result, host_t = None, 0.0
        if fn is not None:
            t0 = time.perf_counter()
            result = fn()
            host_t = time.perf_counter() - t0
        energy = 0.0
        busy = np.zeros(self.profile.n)
        busy[dev] = sim_t
        if self.power is not None:
            energy = self.power.energy(busy, sim_t, gated=asg.gated)
        xfer = self._take_transfers()
        rec = self.ledger.add(PhaseRecord(
            name=name, kind=kind, policy=self.policy.name,
            cost_source=getattr(self.policy, "cost_source", "bytes"),
            cost=cost,
            sim_time_s=sim_t, host_time_s=host_t, energy_j=energy,
            busy_s=[float(b) for b in busy], gated=list(asg.gated),
            device=dev, constraint_violated=asg.constraint_violated,
            h2d_bytes=xfer.h2d_bytes, d2h_bytes=xfer.d2h_bytes,
            syncs=xfer.syncs))
        return result, rec

    # ------------------------------------------------------------------
    # parallel phases: policy plan -> execute -> feedback -> accounting
    # ------------------------------------------------------------------
    def run_phase(self, task: TaskSpec,
                  execute: Callable[[Assignment, np.ndarray], MeasuredPhase],
                  tile_costs: Optional[np.ndarray] = None,
                  tile_flops: Optional[np.ndarray] = None,
                  assignment: Optional[Assignment] = None,
                  extra_switches: int = 0,
                  extra_reissued: int = 0,
                  spinup_from: Optional[int] = None):
        """Run one parallel phase end to end; returns ``(result, record)``.

        ``assignment`` pins the plan (the sharded plane's shard layout *is*
        the assignment — the policy still gets measurement feedback, but
        planning is the plane's shard planner).  ``extra_switches`` /
        ``extra_reissued`` charge planner moves made outside the policy
        (shard re-plans).  ``spinup_from`` charges one switch per core
        activated away from the given device (the serving plane's
        admission-core semantics).
        """
        n_tiles = task.n_tiles or 1
        if tile_costs is None:
            costs = np.full(n_tiles, task.tile_cost(), dtype=np.float64)
        else:
            costs = np.asarray(tile_costs, dtype=np.float64)
        if assignment is None:
            costs = self.policy.tile_costs(self, task, costs, tile_flops)
            asg, plan_sw, plan_re = self.policy.plan(self, task, costs)
        else:
            asg, plan_sw, plan_re = assignment, 0, 0

        measured = execute(asg, costs)

        # model whatever the executor did not measure
        load = np.array([costs[ts].sum() if ts else 0.0
                         for ts in asg.tiles_of])
        if measured.busy_s is None:
            busy = load / self.profile.speeds
        else:
            busy = np.asarray(measured.busy_s, dtype=np.float64)
        makespan = (float(busy.max()) if len(busy) else 0.0) \
            if measured.makespan is None else float(measured.makespan)

        self.policy.feedback(self, task, asg, costs, measured)

        switches = plan_sw + measured.switches + extra_switches
        if spinup_from is not None:
            switches += sum(1 for d, ts in enumerate(asg.tiles_of)
                            if ts and d != spinup_from)
        reissued = plan_re + measured.reissued + extra_reissued

        # energy: gate by what actually ran, not the planned assignment —
        # after a failure re-plan a planned-empty core may have executed
        # orphans (billed active) and a dead core ran nothing (gated)
        gated = [d for d in range(self.profile.n) if busy[d] == 0.0]
        energy = 0.0
        if self.power is not None:
            energy = self.power.energy(busy, makespan, gated=gated,
                                       switches=switches + reissued)
            # a core that died mid-phase worked (active) then powered off:
            # convert its post-death idle tail to gated watts
            for d in measured.failed_devices:
                if busy[d] > 0.0:
                    tail = max(makespan - busy[d], 0.0)
                    energy += (self.power.p_gated[d]
                               - self.power.p_idle[d]) * tail

        xfer = self._take_transfers()
        rec = self.ledger.add(PhaseRecord(
            name=task.name, kind="map", policy=self.policy.name,
            cost_source=getattr(self.policy, "cost_source", "bytes"),
            cost=task.cost, sim_time_s=makespan,
            host_time_s=measured.wall_s, energy_j=energy,
            switches=switches, reissued=reissued,
            busy_s=[float(b) for b in busy], gated=gated,
            n_tiles=n_tiles,
            tiles_done=(list(measured.tiles_done)
                        if measured.tiles_done is not None
                        else [len(ts) for ts in asg.tiles_of]),
            failed_devices=list(measured.failed_devices),
            h2d_bytes=xfer.h2d_bytes + measured.h2d_bytes,
            d2h_bytes=xfer.d2h_bytes + measured.d2h_bytes,
            syncs=xfer.syncs + measured.syncs))
        return measured.result, rec

    # ------------------------------------------------------------------
    def charge_moves(self, rec: PhaseRecord, switches: int = 0,
                     reissued: int = 0) -> PhaseRecord:
        """Attach planner moves to an already-recorded phase and price them
        through the power model — for moves consumed by a round that ran no
        map phase to carry them (a shard re-plan whose candidate generation
        came up dry)."""
        rec.switches += switches
        rec.reissued += reissued
        if self.power is not None and (switches or reissued):
            rec.energy_j += self.power.energy(
                np.zeros(self.profile.n), 0.0,
                gated=list(range(self.profile.n)),
                switches=switches + reissued)
        return rec

    # ------------------------------------------------------------------
    def pinned_assignment(self, costs: np.ndarray) -> Assignment:
        """One tile per device with the given cost — the sharded plane's
        shard layout expressed as an Assignment (rank d owns tile d)."""
        costs = np.asarray(costs, dtype=np.float64)
        tiles_of = [[d] if costs[d] > 0 else [] for d in range(len(costs))]
        finish = costs / self.profile.speeds
        gated = [d for d in range(len(costs)) if not tiles_of[d]]
        return Assignment(tiles_of, finish, gated=gated)
