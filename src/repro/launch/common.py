"""Shared CLI surface for the launch entry points.

``mine``, ``recommend`` and ``stream`` drive the same substrate (corpus
generation, the heterogeneity profile, the switching policy, the kernel
data plane), so the flags that select it are declared once here and
attached by each entry point.  This is what keeps the CLIs from drifting:
``recommend`` once hardcoded its ``--policy`` choices and silently fell
behind ``POLICY_NAMES`` — a flag added here shows up everywhere with the
same name, default and help text.

Each ``add_*`` helper attaches one coherent flag group to an existing
parser; ``standard_parser()`` builds a parser with all of them for the
entry points that want the full set.
"""
from __future__ import annotations

import argparse

from repro.core.hetero import HeterogeneityProfile
from repro.runtime import POLICY_NAMES

# Named core profiles every CLI's --profile resolves through (paper §IV:
# one fast core + progressively slower ones; the alternatives isolate the
# scheduler's contribution).
PROFILES = {
    "paper": HeterogeneityProfile.paper,
    "homogeneous": lambda: HeterogeneityProfile.homogeneous(4, 200.0),
    "straggler": lambda: HeterogeneityProfile.straggler(8, 2, 4.0),
}


def add_corpus_args(ap: argparse.ArgumentParser, n_tx: int = 8192,
                    n_items: int = 128, min_support: float = 0.02,
                    min_confidence: float = 0.6) -> argparse.ArgumentParser:
    """Synthetic-corpus shape and mining thresholds."""
    ap.add_argument("--n-tx", type=int, default=n_tx)
    ap.add_argument("--n-items", type=int, default=n_items)
    ap.add_argument("--min-support", type=float, default=min_support)
    ap.add_argument("--min-confidence", type=float, default=min_confidence)
    return ap


def add_runtime_args(ap: argparse.ArgumentParser,
                     policy: str = "static",
                     split: str = "lpt") -> argparse.ArgumentParser:
    """Heterogeneity profile + switching policy + tile split."""
    ap.add_argument("--profile", default="paper", choices=sorted(PROFILES))
    ap.add_argument("--policy", default=policy, choices=list(POLICY_NAMES),
                    help="switching policy: plan once (static), closed-loop "
                         "EWMA + speculation (dynamic), roofline-seeded "
                         "costs (costmodel)")
    ap.add_argument("--split", default=split,
                    choices=["lpt", "proportional", "equal"],
                    help="tile split strategy across the core profile")
    return ap


def add_dataplane_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Kernel backend selection + autotune winner cache."""
    ap.add_argument("--data-plane", default="auto",
                    choices=["auto", "pallas", "ref"])
    ap.add_argument("--autotune", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="use the checked-in kernel winner cache for "
                         "variant/tile selection (--no-autotune = "
                         "roofline-seeded defaults)")
    return ap


def add_seed_arg(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    ap.add_argument("--seed", type=int, default=0)
    return ap


def standard_parser(**corpus_defaults) -> argparse.ArgumentParser:
    """Parser with the full shared flag set (corpus, runtime, data plane,
    seed); entry points add their own flags on top."""
    ap = argparse.ArgumentParser()
    add_corpus_args(ap, **corpus_defaults)
    add_runtime_args(ap)
    add_dataplane_args(ap)
    add_seed_arg(ap)
    return ap
