"""Sweep the kernel tile spaces and (re)write the autotune winner cache.

  PYTHONPATH=src python -m repro.launch.autotune            # full lattice
  PYTHONPATH=src python -m repro.launch.autotune --smoke    # CI: tiny sweep
  PYTHONPATH=src python -m repro.launch.autotune --out /tmp/cache.json

Every candidate config is measured (synced warmup + median of ``--reps``
synced repetitions) *and* verified bit-identical against the Python
oracle before it may win; configs that disagree are excluded from the
argmin, so a cache entry is both the fastest and a correct configuration
for its (kernel, shape-bucket, device kind).  The default ``--out`` is
the checked-in cache the ops wrappers read
(:data:`repro.kernels.autotune.cache.DEFAULT_CACHE_PATH`) — refresh it on
the device class the benchmarks run on.

``--smoke`` sweeps one small shape per kernel with 2 candidate configs
and writes to a scratch path by default: it exists to exercise the whole
tune → verify → cache → resolve loop in CI, not to produce good tiles.
"""
from __future__ import annotations

import argparse

from repro.kernels.autotune.cache import (DEFAULT_CACHE_PATH, AutotuneCache,
                                          device_kind)
from repro.kernels.autotune.tuner import standard_shapes, tune_into
from repro.launch.common import add_seed_arg
from repro.launch.tuning import TUNABLE_KERNELS


def autotune(out: str = DEFAULT_CACHE_PATH, smoke: bool = False,
             reps: int = 3, max_configs: int = 0, seed: int = 0,
             kernels: tuple = TUNABLE_KERNELS):
    """Run the sweep and write the cache; returns the AutotuneCache."""
    if smoke and not max_configs:
        max_configs = 2
    cache = AutotuneCache.load(out)
    if cache.load_error:
        print(f"[autotune] starting fresh: {cache.load_error}")
    print(f"[autotune] device={device_kind()} smoke={smoke} "
          f"reps={reps} max_configs={max_configs or 'all'}")
    for kernel in kernels:
        shapes = standard_shapes(kernel, smoke=smoke)
        tune_into(cache, kernel, shapes, log=print, reps=reps,
                  max_configs=max_configs, seed=seed)
    path = cache.save(out)
    print(f"[autotune] wrote {len(cache)} entries to {path}")
    return cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_CACHE_PATH,
                    help="cache file to update (default: the checked-in "
                         "cache the ops wrappers read)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one small shape per kernel, 2 configs "
                         "— exercises the tune/verify/cache loop only")
    ap.add_argument("--reps", type=int, default=3,
                    help="synced repetitions per config (median wins)")
    ap.add_argument("--max-configs", type=int, default=0,
                    help="truncate the roofline-ordered candidate list "
                         "(0 = sweep all)")
    add_seed_arg(ap)                # shared with the other launch CLIs
    ap.add_argument("--kernel", action="append", default=None,
                    choices=list(TUNABLE_KERNELS),
                    help="restrict to one kernel (repeatable)")
    args = ap.parse_args()
    autotune(args.out, smoke=args.smoke, reps=args.reps,
             max_configs=args.max_configs, seed=args.seed,
             kernels=tuple(args.kernel) if args.kernel else TUNABLE_KERNELS)


if __name__ == "__main__":
    main()
