"""The continuously-operating system: stream micro-batches through the
incremental :class:`StreamingMiner`, hot-swapping fresh rules into a live
:class:`RecommendationEngine` — mining, serving and the scheduler runtime
running as one closed loop.

  PYTHONPATH=src python -m repro.launch.stream --n-tx 8192 --window 2048 \
      --batch 128 --min-support 0.02 --policy dynamic

``--smoke`` is the CI cross-plane gate: it runs K micro-batches and
asserts the final streaming state (frequent itemsets, supports, rules) is
bit-identical to a one-shot :class:`MarketBasketPipeline` over the same
window — under BOTH the static and the dynamic switching policy, since
scheduling must never change what gets mined — and that the live serving
index was refreshed monotonically and answers from the freshest rules.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.data.baskets import BasketConfig, generate_baskets
from repro.launch.common import PROFILES, standard_parser
from repro.pipeline import MarketBasketPipeline
from repro.serving import (Query, RecommendationEngine, RuleIndex,
                           ServingConfig, recommend_bruteforce)
from repro.streaming import StreamingConfig, StreamingMiner, TransactionStream


def _run_stream(T: np.ndarray, cfg: StreamingConfig, profile_name: str,
                policy: str, serve_k: int, batches: int):
    """One streaming run with a live engine attached; returns the miner,
    its report and the engine."""
    profile = PROFILES[profile_name]()
    n_items = T.shape[1]
    engine = RecommendationEngine(
        RuleIndex.build([], n_items), PROFILES[profile_name](),
        ServingConfig(k=min(serve_k, n_items), data_plane=cfg.data_plane,
                      policy=policy, split=cfg.split,
                      autotune=cfg.autotune))
    miner = StreamingMiner(n_items, profile=profile, config=cfg,
                           engine=engine, policy=policy)
    report = miner.run(TransactionStream(T, cfg.batch_size),
                       max_batches=batches or None)
    return miner, report, engine


def stream(n_tx: int = 8192, n_items: int = 128, window: int = 2048,
           batch: int = 128, batches: int = 0, min_support: float = 0.02,
           min_confidence: float = 0.6, profile_name: str = "paper",
           policy: str = "static", split: str = "lpt",
           data_plane: str = "auto", n_tiles: int = 8,
           refresh_every: int = 1, revalidate_every: int = 0,
           serve_k: int = 5, seed: int = 0, top: int = 10,
           smoke: bool = False, autotune: bool = True):
    if smoke:                       # CI-sized: parity is the point, not scale
        n_tx, n_items = min(n_tx, 1536), min(n_items, 48)
        window, batch = min(window, 512), min(batch, 64)
        # high enough that the stationary segment's noise items sit many
        # standard deviations below the threshold — the lattice must be
        # able to settle or the delta-path assertion below can never hold
        min_support = max(min_support, 0.08)
        # two regimes, both must stay exact: a Zipf-noise segment whose
        # threshold churn forces re-validations, then a stationary
        # wide-margin segment longer than the window so the final batches
        # run the delta-only path the plane exists for (asserted below —
        # a smoke that re-validates every batch would never catch a
        # broken delta update)
        from repro.data.baskets import stationary_baskets
        half = max(window + 2 * batch, n_tx // 2)
        T = np.vstack([
            generate_baskets(BasketConfig(n_tx=max(n_tx - half, batch),
                                          n_items=n_items, seed=seed)),
            stationary_baskets(half, n_items, seed=seed + 1)])
    else:
        T = generate_baskets(BasketConfig(n_tx=n_tx, n_items=n_items,
                                          seed=seed))
    cfg = StreamingConfig(window=window, batch_size=batch,
                          min_support=min_support,
                          min_confidence=min_confidence, n_tiles=n_tiles,
                          policy=policy, split=split, data_plane=data_plane,
                          autotune=autotune, refresh_every=refresh_every,
                          revalidate_every=revalidate_every)

    # smoke checks every policy the paper contrasts; a plain run honors
    # the requested one
    policies = ("static", "dynamic") if smoke else (policy,)
    miner = report = engine = None
    for pol in policies:
        miner, report, engine = _run_stream(T, cfg, profile_name, pol,
                                            serve_k, batches)
        print(f"[stream] policy={pol}")
        print(report.summary())
        if not smoke:
            break

        # ---- parity gate: incremental == one-shot over the same window
        single = MarketBasketPipeline(
            PROFILES[profile_name](),
            cfg.pipeline_config(policy=pol)).run(miner.window.rows_raw())
        assert miner.supports == single.supports, \
            f"streaming vs one-shot itemset mismatch (policy={pol})"
        assert miner.rules == single.rules, \
            f"streaming vs one-shot rule mismatch (policy={pol})"

        # ---- the delta path actually ran: the stationary tail must not
        # re-validate (otherwise this gate only ever tests full Apriori)
        tail = report.batches[-3:]
        assert tail and not any(b.revalidated for b in tail), \
            f"stationary tail re-validated (policy={pol}) — delta path untested"
        assert report.n_revalidations < report.n_batches

        # ---- serving gate: the hot-swapped index answers from the
        # freshest rules (monotone swaps, cache invalidated)
        assert engine.index.version == miner.index.version
        assert any(b.index_swapped for b in report.batches)
        rng = np.random.default_rng(seed + 17)
        for _ in range(32):
            basket = sorted(rng.choice(n_items, size=3, replace=False)
                            .tolist())
            got = engine.recommend(Query.of(basket))
            want = recommend_bruteforce(miner.rules, basket,
                                        engine.config.k)
            assert got == want, (basket, got, want)
        print(f"[stream] smoke OK (policy={pol}): "
              f"{len(miner.supports)} itemsets, {len(miner.rules)} rules "
              f"bit-identical to the one-shot pipeline over the final "
              f"{miner.window.n}-tx window; index v{engine.index.version} "
              f"serves the freshest rules")

    if not smoke and miner is not None:
        print(f"[stream] top rules (min_conf={min_confidence}):")
        for r in miner.rules[:top]:
            print("   ", r)
    return miner, report


def main():
    ap = standard_parser()          # corpus / runtime / data-plane / seed
    ap.add_argument("--window", type=int, default=2048,
                    help="sliding-window capacity (transactions)")
    ap.add_argument("--batch", type=int, default=128,
                    help="micro-batch size (transactions per arrival)")
    ap.add_argument("--batches", type=int, default=0,
                    help="stop after this many micro-batches (0 = all)")
    ap.add_argument("--n-tiles", type=int, default=8,
                    help="map tiles for full re-validation passes")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="micro-batches between rule/index refreshes")
    ap.add_argument("--revalidate-every", type=int, default=0,
                    help="force a periodic full Apriori pass (0 = only "
                         "when the candidate lattice can change)")
    ap.add_argument("--serve-k", type=int, default=5,
                    help="recommendations per query on the live engine")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small stream; assert final state "
                         "bit-identical to a one-shot pipeline over the "
                         "same window under static AND dynamic policies, "
                         "and that the live index serves the fresh rules")
    args = ap.parse_args()
    try:
        stream(args.n_tx, args.n_items, args.window, args.batch,
               args.batches, args.min_support, args.min_confidence,
               args.profile, args.policy, args.split, args.data_plane,
               args.n_tiles, args.refresh_every, args.revalidate_every,
               args.serve_k, args.seed, smoke=args.smoke,
               autotune=args.autotune)
    except AssertionError as e:
        print(f"[stream] SMOKE FAILED: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
