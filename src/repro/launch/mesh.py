"""Production meshes (task spec, MULTI-POD DRY-RUN §1).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get the 512 placeholder devices.
"""
from __future__ import annotations

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """8-device mini mesh for CI (same axis structure)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
