"""Batched decode server loop: prefill → greedy/temperature decode with a
static-slot batch (wave scheduling).

The dry-run lowers the same ``decode_one`` this loop executes; here it runs
for real on smoke configs, demonstrating cache management, sampling, and
per-wave MB-scheduler accounting (throughput per slot feeds the profile).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.models import transformer as T


def prefill_into_cache(params, cfg: ModelConfig, tokens: jnp.ndarray,
                       max_seq: int):
    """Build the KV cache by running decode_step over the prompt (token at a
    time — simple and uniform across attn/ssm/rwkv caches; a fused prefill
    kernel is the production path lowered in the dry-run)."""
    B, S = tokens.shape
    cache = T.init_cache(cfg, B, max_seq)
    logits = None

    def body(carry, t):
        cache = carry
        logits, cache = T.decode_step(params, cfg, cache, tokens[:, t][:, None], t)
        return cache, logits

    step = jax.jit(lambda c, t: T.decode_step(params, cfg, c, tokens[:, t][:, None], t))
    for t in range(S):
        logits, cache = step(cache, t)
    return logits, cache


def decode(params, cfg: ModelConfig, cache, last_logits, start_pos: int,
           n_new: int, temperature: float = 0.0, seed: int = 0):
    B = last_logits.shape[0]
    key = jax.random.PRNGKey(seed)
    step = jax.jit(lambda c, tok, pos: T.decode_step(params, cfg, c, tok, pos))
    out = []
    logits = last_logits
    for i in range(n_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        if cfg.frontend == "audio" and tok.ndim == 2:
            tok_in = tok[:, None, :]
        else:
            tok_in = tok[:, None]
        out.append(np.asarray(tok))
        logits, cache = step(cache, tok_in, start_pos + i)
    return np.stack(out, axis=1), cache


def serve_demo(arch: str, batch: int = 4, prompt_len: int = 32,
               new_tokens: int = 32, smoke: bool = True,
               temperature: float = 0.0, seed: int = 0) -> Dict:
    cfg = get_config(arch, smoke=smoke)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    max_seq = prompt_len + new_tokens

    t0 = time.time()
    if cfg.frontend == "audio":
        prompts3 = jnp.repeat(prompts[:, :, None], cfg.n_codebooks, axis=2)
        cache = T.init_cache(cfg, batch, max_seq)
        step = jax.jit(lambda c, tok, pos: T.decode_step(params, cfg, c, tok, pos))
        logits = None
        for t in range(prompt_len):
            logits, cache = step(cache, prompts3[:, t][:, None, :], t)
    else:
        logits, cache = prefill_into_cache(params, cfg, prompts, max_seq)
    t_prefill = time.time() - t0

    t0 = time.time()
    toks, cache = decode(params, cfg, cache, logits, prompt_len, new_tokens,
                         temperature=temperature, seed=seed)
    t_decode = time.time() - t0
    tps = batch * new_tokens / max(t_decode, 1e-9)
    print(f"[serve] {arch}: prefill {prompt_len} tok x{batch} in "
          f"{t_prefill:.2f}s; decoded {new_tokens} x{batch} in {t_decode:.2f}s "
          f"({tps:.1f} tok/s)")
    return {"tokens": toks, "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": tps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    serve_demo(args.arch, batch=args.batch, prompt_len=args.prompt_len,
               new_tokens=args.new_tokens, temperature=args.temperature,
               smoke=args.smoke)


if __name__ == "__main__":
    main()
