"""EXPERIMENTS.md table generator: renders §Dry-run and §Roofline markdown
from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--profile tuned] > tables.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

HBM_PER_CHIP_GB = 16.0


def load(out_dir="results/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(recs, profile="tuned", mesh=None) -> str:
    lines = ["| arch | shape | mesh | compile s | params (B) | active (B) | "
             "mem/dev GB | fits 16GB | flops/dev | HBM bytes/dev | coll bytes/dev | "
             "top collective |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok") or r.get("profile") != profile:
            continue
        if mesh and r.get("mesh_mode") != mesh:
            continue
        peak = r["memory"]["peak_estimate_bytes"] / 1e9
        by_op = r["collectives"]["bytes_by_op"]
        top = max(by_op, key=by_op.get) if by_op else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh_mode']} "
            f"| {r['compile_s']:.0f} | {r['params_total']/1e9:.2f} "
            f"| {r['params_active']/1e9:.2f} | {peak:.1f} "
            f"| {'✅' if peak <= HBM_PER_CHIP_GB else '❌'} "
            f"| {r['cost']['flops']:.2e} | {r['cost']['bytes_accessed']:.2e} "
            f"| {r['collectives']['total_bytes']:.2e} | {top} |")
    return "\n".join(lines)


def roofline_table(recs, profile="tuned", mesh="pod") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | dominant | "
             "MODEL_FLOPS/HLO | roofline frac | one-line bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        "collective": "TP/EP wire volume; fewer/cheaper collectives move it",
        "memory": "HBM traffic; fusion/chunking/recompute-avoidance move it",
        "compute": "MXU-bound; only better kernels/precision move it",
    }
    for r in recs:
        if not r.get("ok") or r.get("profile") != profile:
            continue
        if r.get("mesh_mode") != mesh:
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} "
            f"| {rl['memory_s']:.3f} | {rl['collective_s']:.3f} "
            f"| **{rl['dominant']}** | {rl['useful_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.4f} | {notes[rl['dominant']]} |")
    return "\n".join(lines)


def skipped_table(recs) -> str:
    lines = ["| arch | shape | mesh | reason |", "|---|---|---|---|"]
    seen = set()
    for r in recs:
        if not r.get("skipped"):
            continue
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        lines.append(f"| {r['arch']} | {r['shape']} | both | {r['reason'][:60]}... |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="tuned")
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.out_dir)
    print("### Dry-run (single-pod 16×16)\n")
    print(dryrun_table(recs, args.profile, mesh="pod"))
    print("\n### Dry-run (multi-pod 2×16×16)\n")
    print(dryrun_table(recs, args.profile, mesh="multipod"))
    print("\n### Skipped cells\n")
    print(skipped_table(recs))
    print("\n### Roofline (single-pod)\n")
    print(roofline_table(recs, args.profile, mesh="pod"))


if __name__ == "__main__":
    main()
