"""Mine → compile → serve: the online recommendation path end to end.

Mines association rules with the MarketBasketPipeline, compiles them into a
device-resident :class:`RuleIndex`, then replays a synthetic query trace
through the micro-batching :class:`RecommendationEngine` (admission via
``MBScheduler.assign_serial``, batched scoring via ``assign_parallel``).

  PYTHONPATH=src python -m repro.launch.recommend --n-tx 8192 --queries 2048
  PYTHONPATH=src python -m repro.launch.recommend --smoke
  PYTHONPATH=src python -m repro.launch.recommend --async --target-qps 50 \\
      --slo-ms 500

``--smoke`` shrinks the problem, serves a 1k-query trace on CPU and pins
every batched top-k result to the brute-force Python oracle — a non-zero
exit means the serving data plane and the rule list disagree.

``--async`` drives the continuous-batching :class:`AsyncServer` instead of
the closed-loop ``serve()``: requests are submitted open-loop at
``--target-qps`` (Poisson arrivals) and drained through slot-based
admission on the AOT-warmed bucket ladder, with ``--slo-ms`` arming the
shedding governor.  ``--async --smoke`` additionally pins the async
results bit-identical to the closed-loop oracle under BOTH the static and
the dynamic switching policy — batching decisions must never change what
gets recommended.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.data.baskets import BasketConfig, generate_baskets
from repro.launch.common import PROFILES, standard_parser
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.serving import (AsyncServer, Query, RecommendationEngine,
                           RuleIndex, ServingConfig, recommend_bruteforce)


def synthetic_trace(cfg: BasketConfig, n_queries: int, seed: int,
                    mean_gap_s: float = 0.0):
    """Query baskets drawn from the same store distribution as the corpus
    (fresh seed), with optional exponential inter-arrival gaps."""
    Q = generate_baskets(BasketConfig(**{**cfg.__dict__, "n_tx": n_queries,
                                         "seed": seed}))
    queries = [Query.of(row) for row in Q]
    rng = np.random.default_rng(seed + 1)
    arrival = (np.cumsum(rng.exponential(mean_gap_s, n_queries))
               if mean_gap_s > 0 else None)
    return queries, arrival


def _recommend_async(make_engine, basket_cfg: BasketConfig, n_queries: int,
                     seed: int, mean_gap_s: float, target_qps: float,
                     rules, k: int, smoke: bool, policy: str):
    """Open-loop leg of the CLI: submit/drain on the AsyncServer.

    With ``--smoke`` the async results are pinned bit-identical to a
    fresh closed-loop ``serve()`` run AND the brute-force oracle, under
    both the static and the dynamic switching policy.
    """
    gap = (1.0 / target_qps) if target_qps > 0 else mean_gap_s
    queries, arrival = synthetic_trace(basket_cfg, n_queries, seed + 101,
                                       gap)
    if arrival is None:
        arrival = np.zeros(len(queries))
    policies = ("static", "dynamic") if smoke else (policy,)
    results = report = None
    for pol in policies:
        engine = make_engine(pol)
        server = AsyncServer(engine)
        handles = [server.submit(q, arrival_s=float(a))
                   for q, a in zip(queries, arrival)]
        server.drain()
        report = server.take_report()
        print(f"[recommend] async policy={pol} "
              f"target={target_qps or 'unpaced'} QPS")
        print(report.summary())
        results = [h.result() if h.status == "done" else None
                   for h in handles]

        if smoke:
            # the same trace through the closed-loop shim on a fresh
            # engine must produce byte-for-byte the same recommendations
            want, _ = make_engine(pol).serve(queries, arrival)
            bad = 0
            for h, got, w, q in zip(handles, results, want, queries):
                if h.status != "done":
                    continue
                oracle = recommend_bruteforce(rules,
                                              np.nonzero(q.payload)[0].tolist(), k)
                if got != w or got != oracle:
                    bad += 1
                    if bad <= 3:
                        print(f"[recommend] ASYNC MISMATCH basket="
                              f"{np.nonzero(q.payload)[0].tolist()}\n  async  {got}"
                              f"\n  closed {w}\n  oracle {oracle}",
                              file=sys.stderr)
            if bad:
                print(f"[recommend] ASYNC SMOKE FAILED: {bad}/{len(queries)}"
                      f" requests disagree with the closed-loop oracle "
                      f"(policy={pol})", file=sys.stderr)
                raise SystemExit(1)
            print(f"[recommend] async smoke OK (policy={pol}): "
                  f"{report.n_completed} async results bit-identical to "
                  f"the closed loop and the brute-force oracle "
                  f"({report.n_shed} shed)")
    return results, report


def recommend(n_tx: int = 8192, n_items: int = 128,
              min_support: float = 0.02, min_confidence: float = 0.6,
              profile_name: str = "paper", split: str = "lpt",
              data_plane: str = "auto", n_queries: int = 2048, k: int = 5,
              batch: int = 64, cache_size: int = 4096, seed: int = 0,
              mean_gap_s: float = 0.0, index_dir: str = "",
              smoke: bool = False, top: int = 8, policy: str = "static",
              autotune: bool = True, use_async: bool = False,
              target_qps: float = 0.0, slo_ms: float = 0.0):
    profile = PROFILES[profile_name]()
    basket_cfg = BasketConfig(n_tx=n_tx, n_items=n_items, seed=seed)

    # 1. mine (the offline path)
    pipe = MarketBasketPipeline(
        profile,
        PipelineConfig(min_support=min_support, min_confidence=min_confidence,
                       policy=policy, split=split, data_plane=data_plane,
                       autotune=autotune))
    result = pipe.run(generate_baskets(basket_cfg))
    print(f"[recommend] mined {len(result.rules)} rules from {n_tx} tx "
          f"({result.report.n_rounds} rounds, backend="
          f"{result.report.backend})")

    # 2. compile the rule index (optionally persist it)
    index = RuleIndex.build(result.rules, n_items)
    print(f"[recommend] index: {index.n_rows} rows "
          f"({index.n_rows_padded}x{index.n_items_padded} padded, "
          f"{index.nbytes / 1024:.0f} KiB)")
    if index_dir:
        print(f"[recommend] saved index to {index.save(index_dir)}")

    # 3. replay the synthetic query trace
    buckets = tuple(sorted({1, min(8, batch), batch}))

    def make_engine(pol: str) -> RecommendationEngine:
        return RecommendationEngine(
            index, PROFILES[profile_name](),
            ServingConfig(k=k, batch_buckets=buckets, data_plane=data_plane,
                          cache_size=cache_size, policy=pol, split=split,
                          autotune=autotune, slo_ms=slo_ms))

    if use_async:
        return _recommend_async(make_engine, basket_cfg, n_queries, seed,
                                mean_gap_s, target_qps, result.rules, k,
                                smoke, policy)

    engine = make_engine(policy)
    queries, arrival = synthetic_trace(basket_cfg, n_queries, seed + 101,
                                       mean_gap_s)
    results, report = engine.serve(queries, arrival)
    print(report.summary())
    shown = 0
    for q, recs in zip(queries, results):
        if recs and shown < top:
            items = ",".join(str(i) for i in np.nonzero(q)[0])
            print(f"   basket {{{items}}} -> " +
                  ", ".join(f"{i} ({s:.3f})" for i, s in recs))
            shown += 1

    # 4. smoke gate: every batched result must equal the brute-force oracle
    if smoke:
        bad = 0
        for q, got in zip(queries, results):
            want = recommend_bruteforce(result.rules,
                                        np.nonzero(q.payload)[0].tolist(), k)
            if got != want:
                bad += 1
                if bad <= 3:
                    print(f"[recommend] MISMATCH basket="
                          f"{np.nonzero(q.payload)[0].tolist()}\n  got  {got}"
                          f"\n  want {want}", file=sys.stderr)
        if bad:
            print(f"[recommend] SMOKE FAILED: {bad}/{len(queries)} queries "
                  f"disagree with the brute-force oracle", file=sys.stderr)
            raise SystemExit(1)
        print(f"[recommend] smoke OK: {len(queries)} queries match the "
              f"brute-force oracle exactly")
    return results, report


def main():
    ap = standard_parser()          # corpus / runtime / data-plane / seed
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--batch", type=int, default=64,
                    help="largest admission bucket")
    ap.add_argument("--cache-size", type=int, default=4096,
                    help="LRU entries; 0 disables the result cache")
    ap.add_argument("--mean-gap-s", type=float, default=0.0,
                    help="mean simulated inter-arrival gap (0 = all at once)")
    ap.add_argument("--index-dir", default="",
                    help="persist the compiled index here (checkpoint store)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve open-loop through the continuous-batching "
                         "AsyncServer (submit/poll/drain) instead of the "
                         "closed-loop serve()")
    ap.add_argument("--target-qps", type=float, default=0.0,
                    help="open-loop Poisson arrival rate for --async "
                         "(0 = unpaced, all requests at t=0)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="latency budget for --async: the governor sheds "
                         "requests projected to miss it (0 = never shed)")
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus, 1k queries, verify vs oracle "
                         "(with --async: pin async == closed-loop == oracle "
                         "under static AND dynamic policies)")
    args = ap.parse_args()
    if args.smoke:
        args.n_tx, args.n_items, args.queries = 2048, 64, 1000
        args.min_support = max(args.min_support, 0.03)
    recommend(args.n_tx, args.n_items, args.min_support, args.min_confidence,
              args.profile, args.split, args.data_plane, args.queries,
              args.k, args.batch, args.cache_size, args.seed, args.mean_gap_s,
              args.index_dir, args.smoke, policy=args.policy,
              autotune=args.autotune, use_async=args.use_async,
              target_qps=args.target_qps, slo_ms=args.slo_ms)


if __name__ == "__main__":
    main()
