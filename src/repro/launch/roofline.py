"""Roofline-term derivation from dry-run compile artifacts (task §ROOFLINE).

Per (arch × shape × mesh):

  compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory_s     = HLO_bytes_per_device / HBM_BW
  collective_s = collective_bytes_per_device / LINK_BW

``compiled.cost_analysis()`` on the SPMD-partitioned module reports
*per-device* flops/bytes, so the task formula ``global / (chips × peak)``
is applied in its per-device form (identical value, no chip count needed).

collective_bytes comes from parsing the partitioned HLO: we sum wire bytes
per device for every collective:
  all-gather          → result bytes (what a device receives)
  all-reduce          → 2 × result bytes (ring: reduce-scatter + all-gather)
  reduce-scatter      → result bytes × group size (what a device sends)
  all-to-all          → result bytes
  collective-permute  → result bytes
Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|"
                      r"u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Wire bytes per device from partitioned HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        op = next((c for c in _COLLECTIVES
                   if re.search(rf"\b{c}(\.\d+)?\(", line)), None)
        if op is None:
            continue
        if line.startswith("%" + op) or f" {op}(" in line or f"= {op}" in line:
            head = line.split(f" {op}")[0] if f" {op}" in line else line.split("(")[0]
        else:
            head = line.split("(")[0]
        result_bytes = sum(_shape_bytes(t, d) for t, d in _TYPE_RE.findall(head))
        if result_bytes == 0:
            continue
        factor = 1.0
        if op == "all-reduce":
            factor = 2.0
        elif op == "reduce-scatter":
            m = _GROUPS_RE.search(line)
            factor = float(m.group(2)) if m else 1.0
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + int(result_bytes * factor)
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: int
    model_flops: float
    useful_ratio: float                  # MODEL_FLOPS / (HLO_FLOPs × chips)
    dominant: str = ""

    def __post_init__(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound spent on *useful* model math at peak:
        (MODEL_FLOPS / chips / PEAK) / max(term)."""
        if self.bound_s <= 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.bound_s


def derive_terms(cost: Dict[str, float], coll: CollectiveStats, chips: int,
                 model_flops_global: float) -> RooflineTerms:
    flops_pd = float(cost.get("flops", 0.0))
    bytes_pd = float(cost.get("bytes accessed", 0.0))
    cbytes = coll.total_bytes
    model_pd = model_flops_global / chips
    return RooflineTerms(
        compute_s=flops_pd / PEAK_FLOPS,
        memory_s=bytes_pd / HBM_BW,
        collective_s=cbytes / LINK_BW,
        flops_per_device=flops_pd,
        bytes_per_device=bytes_pd,
        collective_bytes=cbytes,
        model_flops=model_pd,
        useful_ratio=(model_pd / flops_pd) if flops_pd else 0.0,
    )


def model_flops_for(cfg, shape, n_params_active: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference (fwd only)."""
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
