"""End-to-end trainer: checkpoint/restart, heterogeneity-aware data plan,
straggler handling, optional gradient compression.

Runs real steps on whatever devices exist (CPU smoke configs here; the same
code path drives a pod via the production mesh).  The MB-scheduler features
are exercised for real: per-step the data plan assigns microbatch counts per
rank ∝ measured throughput; injected faults trigger checkpoint-restore and
elastic re-planning.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --restore
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.base import ModelConfig, get_config
from repro.core.hetero import HeterogeneityProfile
from repro.data.sharding import plan_batches
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.distributed.fault import FaultPlan, RestartPolicy, detect_stragglers
from repro.launch import steps as S
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state


def make_batch_for(cfg: ModelConfig, pipeline: TokenPipeline, step: int,
                   batch: int, seq: int) -> Dict[str, jnp.ndarray]:
    b = pipeline.batch(step, batch)
    out = {"tokens": jnp.asarray(b["tokens"][:, :seq])}
    if cfg.frontend == "audio":
        toks = np.stack([b["tokens"][:, :seq]] * cfg.n_codebooks, axis=-1)
        rng = np.random.default_rng(step)
        out = {
            "frames": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(toks % cfg.vocab_size, jnp.int32),
        }
    elif cfg.frontend == "vision":
        rng = np.random.default_rng(step)
        out["vision_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    return out


def train(arch: str, steps: int = 50, smoke: bool = True,
          batch: int = 8, seq: int = 128, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 20, restore: bool = False,
          fault_plan: Optional[FaultPlan] = None,
          profile: Optional[HeterogeneityProfile] = None,
          grad_accum: int = 1, lr: float = 1e-3,
          log_every: int = 10, seed: int = 0) -> Dict[str, list]:
    cfg = get_config(arch, smoke=smoke)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                          total_steps=steps)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    start_step = 0

    if ckpt_dir and restore and store.latest_step(ckpt_dir) is not None:
        (params, opt_state), extra = store.restore(
            ckpt_dir, (params, opt_state))
        start_step = int(extra.get("step", 0))
        print(f"[train] restored step {start_step} from {ckpt_dir}")

    pipeline = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch, seed=seed))
    step_fn = jax.jit(S.make_train_step(cfg, opt_cfg, grad_accum))
    policy = RestartPolicy(checkpoint_every=ckpt_every)

    # MB-scheduler data plan over (possibly heterogeneous) ranks
    profile = profile or HeterogeneityProfile.homogeneous(1)
    plan = plan_batches(profile, batch, max(batch // max(profile.n, 1), 1))

    history = {"loss": [], "step_time": [], "replans": 0}
    t_last = time.time()
    for step in range(start_step, steps):
        if fault_plan:
            for ev in fault_plan.at(step):
                if ev.kind == "device_loss":
                    newp = policy.on_device_loss(profile, ev.device)
                    if newp is not None:
                        profile = newp
                        plan = plan_batches(profile, batch, plan.microbatch)
                        history["replans"] += 1
                        print(f"[fault] step {step}: lost device {ev.device}; "
                              f"elastic shrink to {profile.n} ranks")
                elif ev.kind == "straggler":
                    profile.observe(ev.device, 1.0, ev.severity)
                    plan = plan_batches(profile, batch, plan.microbatch)
                    history["replans"] += 1
                    print(f"[fault] step {step}: straggler {ev.device} "
                          f"(x{ev.severity}); re-planned shares "
                          f"{plan.counts.tolist()}")

        data = make_batch_for(cfg, pipeline, step, batch, seq)
        params, opt_state, metrics = step_fn(params, opt_state, data)
        loss = float(metrics["loss"])
        dt = time.time() - t_last
        t_last = time.time()
        history["loss"].append(loss)
        history["step_time"].append(dt)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms, lr {float(metrics['lr']):.2e}, "
                  f"gnorm {float(metrics['grad_norm']):.2f})")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            store.save(ckpt_dir, step + 1, (params, opt_state),
                       extra={"step": step + 1, "arch": arch})
    if ckpt_dir:
        store.save(ckpt_dir, steps, (params, opt_state),
                   extra={"step": steps, "arch": arch})
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--inject-straggler", type=int, default=-1,
                    help="step at which to inject a 4x straggler")
    args = ap.parse_args()
    fp = None
    if args.inject_straggler >= 0:
        from repro.distributed.fault import FaultEvent
        fp = FaultPlan([FaultEvent(step=args.inject_straggler,
                                   kind="straggler", device=0, severity=4.0)])
    train(args.arch, steps=args.steps, smoke=args.smoke, batch=args.batch,
          seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          restore=args.restore, grad_accum=args.grad_accum, lr=args.lr,
          fault_plan=fp)


if __name__ == "__main__":
    main()
