"""The paper's job, end to end: mine association rules from a transactional
database through :class:`repro.pipeline.MarketBasketPipeline` (MapReduce
Apriori under the MB Scheduler on a heterogeneous core profile).

  PYTHONPATH=src python -m repro.launch.mine --n-tx 8192 --n-items 128 \
      --min-support 0.02 --min-confidence 0.6 --profile paper --policy lpt
"""
from __future__ import annotations

import argparse

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.pipeline import MarketBasketPipeline, PipelineConfig


PROFILES = {
    "paper": HeterogeneityProfile.paper,
    "homogeneous": lambda: HeterogeneityProfile.homogeneous(4, 200.0),
    "straggler": lambda: HeterogeneityProfile.straggler(8, 2, 4.0),
}


def mine(n_tx: int = 8192, n_items: int = 128, min_support: float = 0.02,
         min_confidence: float = 0.6, profile_name: str = "paper",
         policy: str = "lpt", n_tiles: int = 32, data_plane: str = "auto",
         seed: int = 0, top: int = 15):
    profile = PROFILES[profile_name]()
    print(f"[mine] profile={profile_name} speeds={profile.speeds.tolist()} "
          f"policy={policy}")

    T = generate_baskets(BasketConfig(n_tx=n_tx, n_items=n_items, seed=seed))
    pipe = MarketBasketPipeline(
        profile,
        PipelineConfig(min_support=min_support, min_confidence=min_confidence,
                       n_tiles=n_tiles, policy=policy, data_plane=data_plane))
    result = pipe.run(T)

    print(result.report.summary())
    print(f"[mine] top rules (min_conf={min_confidence}):")
    for r in result.rules[:top]:
        print("   ", r)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-tx", type=int, default=8192)
    ap.add_argument("--n-items", type=int, default=128)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--min-confidence", type=float, default=0.6)
    ap.add_argument("--profile", default="paper", choices=sorted(PROFILES))
    ap.add_argument("--policy", default="lpt",
                    choices=["lpt", "proportional", "equal"])
    ap.add_argument("--n-tiles", type=int, default=32)
    ap.add_argument("--data-plane", default="auto",
                    choices=["auto", "pallas", "ref"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    mine(args.n_tx, args.n_items, args.min_support, args.min_confidence,
         args.profile, args.policy, args.n_tiles, args.data_plane, args.seed)


if __name__ == "__main__":
    main()
