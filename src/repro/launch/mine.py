"""The paper's job, end to end: mine association rules from a transactional
database with the 3-step MapReduce pipeline under the MB Scheduler on a
heterogeneous core profile.

  PYTHONPATH=src python -m repro.launch.mine --n-tx 8192 --n-items 128 \
      --min-support 0.02 --min-confidence 0.6 --profile paper --policy lpt
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori
from repro.core.mapreduce import SimulatedCluster
from repro.core.power import PowerModel
from repro.core.rules import generate_rules
from repro.core.scheduler import MBScheduler
from repro.data.baskets import BasketConfig, generate_baskets, pad_items


def mine(n_tx: int = 8192, n_items: int = 128, min_support: float = 0.02,
         min_confidence: float = 0.6, profile_name: str = "paper",
         policy: str = "lpt", n_tiles: int = 32, use_pallas: bool = False,
         seed: int = 0, top: int = 15):
    profiles = {
        "paper": HeterogeneityProfile.paper,
        "homogeneous": lambda: HeterogeneityProfile.homogeneous(4, 200.0),
        "straggler": lambda: HeterogeneityProfile.straggler(8, 2, 4.0),
    }
    profile = profiles[profile_name]()
    print(f"[mine] profile={profile_name} speeds={profile.speeds.tolist()} "
          f"policy={policy}")

    T = generate_baskets(BasketConfig(n_tx=n_tx, n_items=n_items, seed=seed))
    T = pad_items(T)
    min_sup_abs = max(1, int(min_support * n_tx))

    sched = MBScheduler(profile, policy=policy)
    cluster = SimulatedCluster(profile, scheduler=sched,
                               power=PowerModel.cpu(profile))
    t0 = time.time()
    result = apriori(T, min_sup_abs, cluster=cluster, n_tiles=n_tiles,
                     use_pallas=use_pallas)
    wall = time.time() - t0
    rules = generate_rules(result, min_confidence)

    sim_time = sum(rep.makespan for _, rep in result.reports)
    energy = sum(rep.energy_j or 0.0 for _, rep in result.reports)
    print(f"[mine] {len(result.supports)} frequent itemsets "
          f"(levels 1..{result.levels}), {len(rules)} rules, "
          f"wall {wall:.2f}s, simulated cluster makespan {sim_time:.4f}s, "
          f"energy {energy:.1f} J")
    for tag, rep in result.reports:
        print(f"    {tag}: makespan={rep.makespan:.4f}s "
              f"switches={rep.switches} reissued={rep.reissued}")
    print(f"[mine] top rules (min_conf={min_confidence}):")
    for r in rules[:top]:
        print("   ", r)
    return result, rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-tx", type=int, default=8192)
    ap.add_argument("--n-items", type=int, default=128)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--min-confidence", type=float, default=0.6)
    ap.add_argument("--profile", default="paper",
                    choices=["paper", "homogeneous", "straggler"])
    ap.add_argument("--policy", default="lpt",
                    choices=["lpt", "proportional", "equal"])
    ap.add_argument("--n-tiles", type=int, default=32)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    mine(args.n_tx, args.n_items, args.min_support, args.min_confidence,
         args.profile, args.policy, args.n_tiles, args.use_pallas, args.seed)


if __name__ == "__main__":
    main()
