"""The paper's job, end to end: mine association rules from a transactional
database through :class:`repro.pipeline.MarketBasketPipeline` (MapReduce
Apriori under the MB Scheduler on a heterogeneous core profile).

  PYTHONPATH=src python -m repro.launch.mine --n-tx 8192 --n-items 128 \
      --min-support 0.02 --min-confidence 0.6 --profile paper \
      --policy dynamic --split lpt

`--policy` selects the switching policy (paper §VI): ``static`` plans each
phase once, ``dynamic`` closes the loop (EWMA speed feedback, straggler
speculation), ``costmodel`` seeds tile costs from roofline estimates.
`--split` selects the tile split (``lpt`` | ``proportional`` | ``equal``).

`--algorithm` selects the mining formulation: ``apriori`` (horizontal
bitmap rounds), ``eclat`` (vertical tid-list AND-popcount rounds), or
``auto`` (the algorithm cost model prices both on measured density
features and picks one).  `--dataset sparse` generates a wide-universe
low-frequency corpus consumed through the sparse CSR slab — the Eclat
path then never materializes the dense bitmap.

`--sharded` executes the distributed mining plane instead (shard_map over a
device mesh; run with XLA_FLAGS=--xla_force_host_platform_device_count=8
for a simulated 8-rank CPU mesh), and `--smoke` additionally runs the
single-device pipeline on the same data and asserts bit-identical itemsets
and rules — the CI multi-device end-to-end check (run under both
``--policy static`` and ``--policy dynamic``: results must not depend on
the switching policy, and with ``--algorithm eclat|auto`` the reference
pipeline is the Apriori oracle, so the cross-algorithm parity is asserted
too).

`--out-of-core` runs the SON two-pass plane: the corpus is spilled to
disk-resident chunks of `--partition-rows` transactions under `--son-dir`,
mined partition-locally, then globally re-counted — with a resumable
checkpoint at every partition boundary.  A killed mine (`--kill-after N`
simulates one, exiting 3) restarts with `--resume` from the last completed
partition and finishes bit-identical to an uninterrupted run; the
`--smoke` oracle assert is the proof, and the CI kill-and-resume smoke
drives exactly that sequence.
"""
from __future__ import annotations

import contextlib
import os
import tempfile

from repro.data.baskets import BasketConfig, generate_baskets, sparse_baskets
from repro.data.sparse import SparseSlab
from repro.launch.common import PROFILES, standard_parser
from repro.pipeline import MarketBasketPipeline, PipelineConfig


def _make_dataset(dataset: str, n_tx: int, n_items: int, seed: int):
    """dense → 0/1 bitmap; sparse → CSR slab (never densified here)."""
    if dataset == "sparse":
        baskets = sparse_baskets(n_tx, max(n_items, 256), seed=seed,
                                 max_item_freq=0.05)
        return SparseSlab.from_baskets(baskets, n_items=max(n_items, 256))
    return generate_baskets(BasketConfig(n_tx=n_tx, n_items=n_items,
                                         seed=seed))


def mine(n_tx: int = 8192, n_items: int = 128, min_support: float = 0.02,
         min_confidence: float = 0.6, profile_name: str = "paper",
         split: str = "lpt", n_tiles: int = 32, data_plane: str = "auto",
         seed: int = 0, top: int = 15, sharded: bool = False,
         n_shards: int = 0, smoke: bool = False, policy: str = "static",
         autotune: bool = True, algorithm: str = "apriori",
         dataset: str = "dense", round_execution: str = "pipelined",
         profile_dir: str = "", out_of_core: bool = False,
         partition_rows: int = 4096, son_dir: str = "", resume: bool = False,
         kill_after: int = 0):
    if smoke:                       # CI-sized: parity is the point, not scale
        n_tx, n_items = min(n_tx, 2048), min(n_items, 64)
        if out_of_core:             # at least 4 partitions, so the two-pass
            partition_rows = min(partition_rows, max(256, n_tx // 4))

    T = _make_dataset(dataset, n_tx, n_items, seed)
    config = PipelineConfig(min_support=min_support,
                            min_confidence=min_confidence,
                            n_tiles=n_tiles, policy=policy, split=split,
                            data_plane=data_plane, autotune=autotune,
                            algorithm=algorithm,
                            round_execution=round_execution)
    choice = None
    if profile_dir:
        # one device-level trace of the whole mine (dispatch overlap, the
        # single d2h per round) — view with tensorboard or Perfetto
        import jax
        trace_ctx = jax.profiler.trace(profile_dir)
    else:
        trace_ctx = contextlib.nullcontext()

    if out_of_core:
        from repro.mining import SONConfig, SONKilled, SONMiner, make_miner
        workdir = son_dir or os.path.join(tempfile.gettempdir(),
                                          f"repro-son-{seed}")
        son = SONConfig(workdir=workdir, partition_rows=partition_rows,
                        resume=resume, abort_after=kill_after or None)
        profile = PROFILES[profile_name]()
        print(f"[mine] out-of-core: {partition_rows} rows/partition "
              f"workdir={workdir} resume={resume} policy={policy} "
              f"algorithm={algorithm}" + (" sharded" if sharded else ""))
        if sharded:
            # per-partition local pass on a real device mesh
            from repro.distributed.mining import make_shard_mesh
            miner = SONMiner(profile=profile, config=config, son=son,
                             mesh=make_shard_mesh(n_shards or None))
        else:
            miner, _ = make_miner(T, profile=profile, config=config, son=son)
        try:
            with trace_ctx:
                result = miner.run(T)
        except SONKilled as e:
            print(f"[mine] killed at partition boundary {e.boundary} "
                  f"(checkpoint saved under {workdir}) — rerun with "
                  "--resume to finish")
            raise SystemExit(3)
        choice = miner.algorithm_choice
    elif sharded:
        from repro.distributed.mining import (ShardedMiner, make_shard_mesh,
                                              mesh_profile)
        mesh = make_shard_mesh(n_shards or None)
        n = mesh.shape[mesh.axis_names[0]]
        profile = mesh_profile(n, PROFILES[profile_name]())
        print(f"[mine] sharded mesh={n} ranks "
              f"speeds={profile.speeds.tolist()} policy={policy} "
              f"split={split} algorithm={algorithm}")
        miner = ShardedMiner(mesh=mesh, profile=profile, config=config,
                             verify_rounds=smoke)
        with trace_ctx:
            result = miner.run(T)
        choice = miner.algorithm_choice
    else:
        from repro.mining import make_miner
        profile = PROFILES[profile_name]()
        print(f"[mine] profile={profile_name} speeds={profile.speeds.tolist()} "
              f"policy={policy} split={split} algorithm={algorithm}")
        miner, choice = make_miner(T, profile=profile, config=config)
        with trace_ctx:
            result = miner.run(T)

    if choice is not None:
        print(f"[mine] {choice.summary()}")
    print(result.report.summary())
    print(f"[mine] top rules (min_conf={min_confidence}):")
    for r in result.rules[:top]:
        print("   ", r)

    if smoke and (sharded or out_of_core or algorithm != "apriori"):
        # end-to-end cross-plane AND cross-algorithm check: whatever ran
        # (sharded, out-of-core, eclat, auto) must equal the single-device
        # Apriori oracle bit for bit — scheduling, partitioning and
        # formulation must never change what gets mined, only
        # when/where/how it runs
        oracle_cfg = PipelineConfig(
            min_support=min_support, min_confidence=min_confidence,
            n_tiles=n_tiles, policy=policy, split=split,
            data_plane=data_plane, autotune=autotune)
        single = MarketBasketPipeline(PROFILES[profile_name](),
                                      oracle_cfg).run(T)
        assert result.supports == single.supports, \
            "mined itemsets differ from the single-device Apriori oracle"
        assert result.rules == single.rules, \
            "mined rules differ from the single-device Apriori oracle"
        ran = result.report.algorithm + (" sharded" if sharded else "") \
            + (" out-of-core" if out_of_core else "") \
            + (" resumed" if resume else "")
        print(f"[mine] smoke OK: {ran} == single-device apriori "
              f"({len(result.supports)} itemsets, {len(result.rules)} rules, "
              f"policy={policy})")
    return result


def main():
    ap = standard_parser()          # corpus / runtime / data-plane / seed
    ap.add_argument("--algorithm", default="apriori",
                    choices=["apriori", "eclat", "auto"],
                    help="mining formulation: horizontal bitmap (apriori), "
                         "vertical tid-lists (eclat), or cost-model "
                         "selection on measured density features (auto)")
    ap.add_argument("--dataset", default="dense",
                    choices=["dense", "sparse"],
                    help="dense = IBM-Quest bitmap; sparse = wide-universe "
                         "low-frequency corpus via the CSR slab (the Eclat "
                         "path never builds the dense bitmap)")
    ap.add_argument("--n-tiles", type=int, default=32)
    ap.add_argument("--round-execution", default="pipelined",
                    choices=["pipelined", "per_tile"],
                    help="pipelined = async tile dispatch, donated slabs, "
                         "one d2h per counting round; per_tile = legacy "
                         "host readback per tile")
    ap.add_argument("--profile-dir", default="",
                    help="write a jax.profiler device trace of the mine "
                         "here (tensorboard/Perfetto format)")
    ap.add_argument("--sharded", action="store_true",
                    help="execute on the distributed mining plane (shard_map)")
    ap.add_argument("--n-shards", type=int, default=0,
                    help="mesh ranks (default: all visible devices)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small data, per-round invariant checks, "
                         "and (with --sharded / --out-of-core / "
                         "--algorithm eclat|auto) single-device Apriori "
                         "parity assert")
    ap.add_argument("--out-of-core", action="store_true",
                    help="SON two-pass plane: spill the corpus to disk "
                         "chunks, mine partition-locally, re-count "
                         "globally — checkpointed at every boundary")
    ap.add_argument("--partition-rows", type=int, default=4096,
                    help="transactions per disk-resident SON chunk (the "
                         "device-memory budget)")
    ap.add_argument("--son-dir", default="",
                    help="SON workdir for spill chunks + checkpoints "
                         "(default: a per-seed dir under the system tmp)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed out-of-core mine from its last "
                         "completed partition boundary (bit-identical to "
                         "an uninterrupted run)")
    ap.add_argument("--kill-after", type=int, default=0,
                    help="test hook: abort the out-of-core mine after N "
                         "partition boundaries (exit code 3, checkpoint "
                         "kept — the CI kill-and-resume smoke)")
    args = ap.parse_args()
    if args.sharded and "XLA_FLAGS" not in os.environ:
        # default in a multi-device mesh for the CLI only — XLA reads this
        # env at (lazy) backend initialization, which nothing in the import
        # chain above triggers, so setting it here still takes effect
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    mine(args.n_tx, args.n_items, args.min_support, args.min_confidence,
         args.profile, args.split, args.n_tiles, args.data_plane, args.seed,
         sharded=args.sharded, n_shards=args.n_shards, smoke=args.smoke,
         policy=args.policy, autotune=args.autotune,
         algorithm=args.algorithm, dataset=args.dataset,
         round_execution=args.round_execution,
         profile_dir=args.profile_dir, out_of_core=args.out_of_core,
         partition_rows=args.partition_rows, son_dir=args.son_dir,
         resume=args.resume, kill_after=args.kill_after)


if __name__ == "__main__":
    main()
