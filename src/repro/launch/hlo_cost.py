"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every while-loop (scan) body exactly
once (verified empirically — a 10-step scan of a 128³ dot reports 1/10th
the flops of its unrolled twin).  Every production model here scans over
layers / microbatches / attention chunks, so raw cost_analysis under-counts
by 1-3 orders of magnitude.  This module re-derives flops / HBM traffic /
collective bytes by walking the *scheduled, SPMD-partitioned* HLO text:

* computations are parsed into per-op records with a local symbol table
  (op name → result type/shape), so operand shapes resolve exactly;
* ``while`` ops multiply their body cost by the trip count XLA annotates in
  ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the constant
  in the condition's ROOT compare; else 1 + a warning flag);
* flops: dot ops contribute 2·|result|·K (K = contracted extent from the
  lhs operand shape); elementwise flops are ignored (sub-1% for these
  models); fusions are recursed for dots.
* HBM traffic: per op, result + operand bytes, with fusion interiors elided
  (a fusion is one read of its operands + one write of its result — XLA's
  own model) and gather/scatter counted at moved-bytes, not table size.
* collective wire bytes: as in roofline.parse_collectives, but accumulated
  through the weighted call graph.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|s4|u64|u32|u16|u8|u4|"
    r"pred|c64|c128|token)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[\\"=:{\s]+n[\\":\s]+(\d+)')
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes_elems(segment: str) -> Tuple[int, int]:
    total_b, total_e = 0, 0
    for t, dims in _TYPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[t]
        total_e += n
    return total_b, total_e


@dataclass
class _Op:
    name: str
    opcode: str
    result_segment: str
    rest: str
    operands: List[str]


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # name -> result seg


@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Dict[str, float] = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.traffic_bytes * k,
                       self.collective_bytes * k,
                       {o: b * k for o, b in self.collective_by_op.items()},
                       self.unknown_trip_loops)

    def add(self, other: "HloCost"):
        self.flops += other.flops
        self.traffic_bytes += other.traffic_bytes
        self.collective_bytes += other.collective_bytes
        for o, b in other.collective_by_op.items():
            self.collective_by_op[o] = self.collective_by_op.get(o, 0) + b
        self.unknown_trip_loops += other.unknown_trip_loops


_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota", "reshape"}


def _opcode_of(segment: str) -> str:
    """First identifier after the result type(s)."""
    # strip result types: take text after the last ']' or ')' prefix group
    m = re.match(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)", segment)
    return m.group(1) if m else ""


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, _Computation] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, HloCost] = {}
        self.entry = self._entry_name(hlo_text)

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[_Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            header = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
            if header:
                cur = _Computation(header.group(1))
                self.computations[cur.name] = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # result segment = up to the opcode; keep whole rhs for parsing
            opcode = _opcode_of(rhs)
            # operands: first (...) group after opcode
            after = rhs.split(opcode, 1)[1] if opcode and opcode in rhs else rhs
            om = _OPERANDS_RE.search(after)
            operands = []
            if om:
                for tok in om.group(1).split(","):
                    tok = tok.strip()
                    if tok.startswith("%"):
                        operands.append(tok[1:])
                    else:
                        mm = re.search(r"%([\w.\-]+)", tok)
                        if mm:
                            operands.append(mm.group(1))
            op = _Op(name, opcode, rhs.split(opcode)[0], rhs, operands)
            cur.ops.append(op)
            cur.symbols[name] = op.result_segment
        # index by name

    def _entry_name(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%([\w.\-]+)", text, re.M)
        if m:
            return m.group(1)
        return next(reversed(self.computations))

    # ------------------------------------------------------------------
    def _trip_count(self, op: _Op) -> Tuple[float, bool]:
        m = _TRIP_RE.search(op.rest)
        if m:
            return float(m.group(1)), True
        cm = _COND_RE.search(op.rest)
        if cm and cm.group(1) in self.computations:
            cond = self.computations[cm.group(1)]
            consts = {o.name: o for o in cond.ops if o.opcode == "constant"}
            for o in cond.ops:
                if o.opcode in ("compare", "fusion") and consts:
                    vals = []
                    for cn, co in consts.items():
                        vm = re.search(r"constant\((\d+)\)", co.rest)
                        if vm:
                            vals.append(int(vm.group(1)))
                    if vals:
                        return float(max(vals)), True
        return 1.0, False

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: _Computation, op: _Op) -> float:
        rb, relems = _type_bytes_elems(op.result_segment)
        k = 1
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        if cm and op.operands:
            lhs_seg = comp.symbols.get(op.operands[0], "")
            tm = _TYPE_RE.search(lhs_seg)
            if tm:
                dims = [int(d) for d in tm.group(2).split(",") if d]
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * relems * k

    def _op_traffic(self, comp: _Computation, op: _Op) -> float:
        if op.opcode in _SKIP_TRAFFIC:
            return 0.0
        rb, _ = _type_bytes_elems(op.result_segment)
        if op.opcode in ("gather", "dynamic-slice"):
            idx_b = sum(_type_bytes_elems(comp.symbols.get(o, ""))[0]
                        for o in op.operands[1:])
            return 2.0 * rb + idx_b
        if op.opcode in ("scatter", "dynamic-update-slice"):
            upd = op.operands[-1] if op.opcode == "dynamic-update-slice" \
                else (op.operands[1] if len(op.operands) > 1 else op.operands[0])
            ub, _ = _type_bytes_elems(comp.symbols.get(upd, ""))
            return 2.0 * max(ub, 1.0)
        ob = sum(_type_bytes_elems(comp.symbols.get(o, ""))[0]
                 for o in op.operands)
        return rb + ob

    def _collective(self, op: _Op) -> Optional[Tuple[str, float]]:
        if op.opcode not in _COLLECTIVES:
            return None
        rb, _ = _type_bytes_elems(op.result_segment)
        factor = 1.0
        if op.opcode == "all-reduce":
            factor = 2.0
        elif op.opcode == "reduce-scatter":
            m = _GROUPS_RE.search(op.rest)
            factor = float(m.group(2)) if m else 1.0
        return op.opcode, rb * factor

    # ------------------------------------------------------------------
    def cost_of(self, comp_name: str) -> HloCost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.computations.get(comp_name)
        total = HloCost()
        self._memo[comp_name] = total     # cycle guard (shouldn't happen)
        if comp is None:
            return total
        for op in comp.ops:
            col = self._collective(op)
            if col:
                total.collective_bytes += col[1]
                total.collective_by_op[col[0]] = \
                    total.collective_by_op.get(col[0], 0) + col[1]
                total.traffic_bytes += self._op_traffic(comp, op)
                continue
            if op.opcode == "while":
                trips, known = self._trip_count(op)
                bm = _CALL_RE.search(op.rest)
                if bm:
                    body = self.cost_of(bm.group(1)).scaled(trips)
                    total.add(body)
                cm = _COND_RE.search(op.rest)
                if cm:
                    total.add(self.cost_of(cm.group(1)).scaled(trips + 1))
                if not known:
                    total.unknown_trip_loops += 1
                continue
            if op.opcode == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    branch_costs = [self.cost_of(b.strip().lstrip("%"))
                                    for b in bm.group(1).split(",")]
                    if branch_costs:
                        worst = max(branch_costs, key=lambda c: c.flops + c.traffic_bytes)
                        total.add(worst)
                continue
            if op.opcode in ("fusion", "call", "custom-call", "reduce",
                             "sort", "map", "reduce-window", "select-and-scatter"):
                total.traffic_bytes += self._op_traffic(comp, op)
                for sub in _CALL_RE.findall(op.rest):
                    subc = self.cost_of(sub)
                    # fusion interiors: flops + collectives only, no traffic
                    total.flops += subc.flops
                    total.collective_bytes += subc.collective_bytes
                    for o, b in subc.collective_by_op.items():
                        total.collective_by_op[o] = \
                            total.collective_by_op.get(o, 0) + b
                    total.unknown_trip_loops += subc.unknown_trip_loops
                continue
            if op.opcode == "dot":
                total.flops += self._dot_flops(comp, op)
                total.traffic_bytes += self._op_traffic(comp, op)
                continue
            if op.opcode == "convolution":
                rb, relems = _type_bytes_elems(op.result_segment)
                kb, kelems = _type_bytes_elems(
                    comp.symbols.get(op.operands[1], "")) if len(op.operands) > 1 else (0, 1)
                total.flops += 2.0 * relems * max(kelems, 1)
                total.traffic_bytes += self._op_traffic(comp, op)
                continue
            total.traffic_bytes += self._op_traffic(comp, op)
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> HloCost:
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> HloCost:
    return HloCostModel(hlo_text).entry_cost()
