"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: sharding
propagation, collective schedule, memory fit — all from the compiled SPMD
artifact on 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --profile tuned --out results/dryrun
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# at first init):
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config, list_archs
from repro.distributed import meshes as M
from repro.launch import hlo_cost as H
from repro.launch import roofline as R
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.launch.tuning import cell_config
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig


def _active_params(cfg, params_spec) -> int:
    """Active (per-token) parameter count from the abstract pytree."""
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_spec)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = int(np.prod(leaf.shape))
        total += n
        if "/moe/" in key and "/shared/" not in key and "router" not in key:
            routed += n
    if cfg.moe is not None and cfg.moe.n_experts:
        active = total - routed + int(routed * cfg.moe.top_k / cfg.moe.n_experts)
        return active
    return total


def lower_cell(arch: str, shape_name: str, mesh, profile: str = "tuned",
               overrides: Optional[Dict[str, Any]] = None,
               opt_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Lower + compile one cell; returns the artifact record."""
    shape = SHAPES[shape_name]
    cfg0 = get_config(arch)
    if overrides:                      # before tuning so vocab/dims are real
        cfg0 = cfg0.replace(**overrides)
    cfg, opts = cell_config(cfg0, shape_name, profile)
    if overrides:                      # and after, so explicit overrides win
        cfg = cfg.replace(**overrides)
    if opt_overrides:
        opts.update(opt_overrides)
    chips = int(np.prod(list(mesh.shape.values())))

    params_spec = S.param_specs(cfg)
    p_pspec = M.param_pspecs(cfg, params_spec, mesh)
    p_sh = M.named(p_pspec, mesh)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
        "profile": profile, "chips": chips, "kind": shape.kind,
        "config": {"attention_impl": cfg.attention_impl,
                   "attention_chunk": cfg.attention_chunk,
                   "vocab_loss_chunk": cfg.vocab_loss_chunk,
                   "remat_policy": cfg.remat_policy,
                   "sequence_parallel": cfg.sequence_parallel,
                   "grad_accum": opts.get("grad_accum", 1)},
    }
    t0 = time.time()
    from repro.core.compat import mesh_context
    ctx = mesh_context(mesh)          # ambient mesh for sequence_shard
    ctx.__enter__()

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        step_fn = S.make_train_step(cfg, opt_cfg, opts.get("grad_accum", 1))
        opt_spec = S.abstract_opt_state(params_spec)
        o_pspec = M.opt_pspecs(cfg, params_spec, mesh)
        from repro.optim.adamw import OptState
        o_sh = OptState(mu=M.named(o_pspec, mesh), nu=M.named(o_pspec, mesh),
                        step=NamedSharding(mesh, P()))
        batch = S.batch_specs(cfg, shape)
        b_sh = M.named(M.batch_pspecs(batch, mesh), mesh)
        lowered = jax.jit(
            step_fn,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        ).lower(params_spec, opt_spec, batch)
    elif shape.kind == "prefill":
        step_fn = S.make_prefill_step(cfg)
        batch = S.batch_specs(cfg, shape)
        b_sh = M.named(M.batch_pspecs(batch, mesh), mesh)
        lowered = jax.jit(step_fn, in_shardings=(p_sh, b_sh)).lower(
            params_spec, batch)
    else:  # decode
        step_fn = S.make_decode_step(cfg)
        d = S.decode_specs(cfg, shape)
        c_pspec = M.cache_pspecs(cfg, d["cache"], mesh, shape.seq_len)
        c_sh = M.named(c_pspec, mesh)
        tok_pspec = M.batch_pspecs({"t": d["tokens"]}, mesh)["t"]
        tok_sh = NamedSharding(mesh, tok_pspec)
        batch_ax = tok_pspec[0] if len(tok_pspec) else None
        next_rank = 2 if cfg.frontend == "audio" else 1   # [B,K] vs [B]
        next_sh = NamedSharding(
            mesh, P(*((batch_ax,) + (None,) * (next_rank - 1))))
        pos_sh = NamedSharding(mesh, P())
        lowered = jax.jit(
            step_fn,
            in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
            out_shardings=(next_sh, c_sh),
            donate_argnums=(1,),
        ).lower(params_spec, d["cache"], d["tokens"], d["pos"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ctx.__exit__(None, None, None)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    hc = H.analyze(hlo)                    # trip-count-corrected HLO cost

    n_active = _active_params(cfg, params_spec)
    n_total = int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params_spec)))
    mf = R.model_flops_for(cfg, shape, n_active, shape.kind)
    corrected = {"flops": hc.flops, "bytes accessed": hc.traffic_bytes}
    coll = R.CollectiveStats(
        bytes_by_op={k: int(v) for k, v in hc.collective_by_op.items()})
    terms = R.derive_terms(corrected, coll, chips, mf)

    rec.update({
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "params_total": n_total, "params_active": n_active,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   + mem.output_size_in_bytes
                                   - mem.alias_size_in_bytes,
        },
        "cost": {"flops": hc.flops,                      # trip-corrected
                 "bytes_accessed": hc.traffic_bytes,
                 "xla_flops_raw": cost.get("flops", 0.0),
                 "xla_bytes_raw": cost.get("bytes accessed", 0.0),
                 "unknown_trip_loops": hc.unknown_trip_loops},
        "collectives": {"bytes_by_op": coll.bytes_by_op,
                        "total_bytes": coll.total_bytes},
        "roofline": {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s, "dominant": terms.dominant,
            "model_flops_global": mf, "useful_ratio": terms.useful_ratio,
            "roofline_fraction": terms.roofline_fraction,
        },
    })
    return rec


def run_cells(archs, shapes, mesh_modes, profile: str, out_dir: str,
              stop_on_error: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for mesh_mode in mesh_modes:
        mesh = make_production_mesh(multi_pod=(mesh_mode == "multipod"))
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                tag = f"{arch}__{shape_name}__{mesh_mode}__{profile}"
                path = os.path.join(out_dir, tag + ".json")
                if shape_name not in cfg.shapes():
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh_mode": mesh_mode, "ok": False,
                           "skipped": True,
                           "reason": "pure full-attention arch; long-context "
                                     "decode requires sub-quadratic mixer "
                                     "(DESIGN.md §Arch-applicability)"}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[skip] {tag}: inapplicable shape")
                    continue
                if os.path.exists(path):
                    with open(path) as f:
                        old = json.load(f)
                    if old.get("ok"):
                        print(f"[cached] {tag}")
                        results.append(old)
                        continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh, profile)
                    rec["mesh_mode"] = mesh_mode
                    rl = rec["roofline"]
                    print(f"    ok: compile={rec['compile_s']}s "
                          f"dominant={rl['dominant']} "
                          f"compute={rl['compute_s']:.4f}s "
                          f"memory={rl['memory_s']:.4f}s "
                          f"coll={rl['collective_s']:.4f}s "
                          f"frac={rl['roofline_fraction']:.3f}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh_mode": mesh_mode, "profile": profile,
                           "ok": False, "error": str(e)[-2000:],
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"    FAILED: {str(e)[:300]}", flush=True)
                    if stop_on_error:
                        raise
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--profile", default="tuned", choices=["baseline", "tuned"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--stop-on-error", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    mesh_modes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    results = run_cells(archs, shapes, mesh_modes, args.profile, args.out,
                        stop_on_error=args.stop_on_error)
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{ok}/{len(results)} cells compiled OK")


if __name__ == "__main__":
    main()
