"""Per-cell performance configuration (the §Perf levers).

Two profiles:

* ``baseline`` — the paper-faithful starting point: stock XLA attention
  (naive scores where they physically fit, chunked where an S² tensor could
  never be resident), dense vocab loss, full remat, minimal grad-accum.
* ``tuned``    — the beyond-paper hillclimbed settings recorded in
  EXPERIMENTS.md §Perf (chunked/online-softmax attention, chunked vocab
  loss for ≥100k vocabs, remat policy, grad-accum, MoE capacity).

Every entry may override ModelConfig fields and set ``grad_accum``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

from repro.configs.base import ModelConfig

_BIG_VOCAB = 100_000


def pick_vocab_chunk(vocab: int, target: int = 8192, max_chunk: int = 16384) -> int:
    """Largest divisor of `vocab` ≤ max_chunk (0 if only trivial divisors):
    the chunked-logsumexp loss needs V % chunk == 0.  When the vocab is
    16-divisible we also keep the chunk aligned to the per-device vocab
    shard (V/16) so the reshape keeps its "model" sharding."""
    base = vocab // 16 if vocab % 16 == 0 else vocab
    for c in range(min(max_chunk, base), 0, -1):
        if base % c == 0 and vocab % c == 0:
            return c if c > 64 else 0
    return 0


def cell_config(cfg: ModelConfig, shape_name: str, profile: str
                ) -> Tuple[ModelConfig, Dict[str, Any]]:
    """Returns (model config with profile overrides, extra step options)."""
    opts: Dict[str, Any] = {"grad_accum": 1}
    over: Dict[str, Any] = {}

    if profile == "baseline":
        over["remat_policy"] = "full"
        if shape_name == "train_4k":
            # naive attention fits at 4k with grad-accum; S² is sharded
            over["attention_impl"] = "naive"
            opts["grad_accum"] = 8
        elif shape_name == "prefill_32k":
            # a 32k² f32 score tensor can never be resident -> chunked even
            # in the baseline (documented in EXPERIMENTS.md §Dry-run)
            over["attention_impl"] = "chunked"
            over["attention_chunk"] = 2048
        else:
            over["attention_impl"] = "naive"
        return cfg.replace(**over), opts

    # ---- tuned profile (final choices from the §Perf iteration log) ----
    over["remat_policy"] = "full"
    if shape_name == "train_4k":
        # measured: at 4k with head-sharded scores, naive attention beats the
        # chunked scan on HBM traffic; SP doubles AR volume on these
        # collective-bound cells (§Perf C iterations 1-2) -> both off.
        over["attention_impl"] = "naive"
        over["sequence_parallel"] = False
        opts["grad_accum"] = 8
        if cfg.moe is not None and cfg.moe.n_experts:
            opts["grad_accum"] = 16      # MoE dispatch working-set fit
    else:
        # 32k+ sequences: S² scores can never be resident -> online-softmax
        # chunks; these cells are memory-dominant, where SP's sharded
        # residual saves win (§Perf A/dry-run table).
        over["attention_impl"] = "chunked"
        over["attention_chunk"] = 2048
        if shape_name == "prefill_32k":
            over["sequence_parallel"] = True
    if shape_name in ("train_4k", "prefill_32k"):
        # full-sequence recurrences: chunked WKV / log-depth SSM scan
        # (baseline keeps the paper-naive sequential scans: 44-250x — §Perf A)
        over["time_mix_impl"] = "chunked"
        over["ssm_impl"] = "associative"
    # Chunked logsumexp loss: measured NET-NEGATIVE at these shapes even for
    # non-16-divisible vocabs (replicated [T,V] logits fit comfortably at
    # 4k and the chunk scan adds weight re-reads) — granite train frac
    # 0.0490 dense vs 0.0467 chunked.  The lever stays available
    # (`vocab_loss_chunk`) for configs where logits don't fit; see §Perf.
    return cfg.replace(**over), opts
