"""Per-cell performance configuration (the §Perf levers) and the
roofline-seeded kernel tile spaces the autotuner sweeps.

Model-cell profiles (``cell_config``):

* ``baseline`` — the paper-faithful starting point: stock XLA attention
  (naive scores where they physically fit, chunked where an S² tensor could
  never be resident), dense vocab loss, full remat, minimal grad-accum.
* ``tuned``    — the beyond-paper hillclimbed settings recorded in
  EXPERIMENTS.md §Perf (chunked/online-softmax attention, chunked vocab
  loss for ≥100k vocabs, remat policy, grad-accum, MoE capacity).

Kernel tuning seeds (``kernel_candidates`` / ``estimate_cost_us`` /
``default_config``): the config spaces for the Apriori hot-loop kernels
(``support_count``, ``rule_match``) — each candidate names an
implementation *variant* (``mxu`` int8-matmul vs ``packed``
AND-popcount on uint32 words) plus its tile shape — and a roofline cost
model over :mod:`repro.launch.roofline` constants that orders the sweep
and supplies the cold-cache default: when
:mod:`repro.kernels.autotune` has no measurement for a (kernel,
shape-bucket, device), the argmin of the *estimated* costs is used, so a
missing or corrupt cache degrades to roofline-seeded defaults instead of
erroring.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

from repro.configs.base import ModelConfig
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

_BIG_VOCAB = 100_000


def pick_vocab_chunk(vocab: int, target: int = 8192, max_chunk: int = 16384) -> int:
    """Largest divisor of `vocab` ≤ max_chunk (0 if only trivial divisors):
    the chunked-logsumexp loss needs V % chunk == 0.  When the vocab is
    16-divisible we also keep the chunk aligned to the per-device vocab
    shard (V/16) so the reshape keeps its "model" sharding."""
    base = vocab // 16 if vocab % 16 == 0 else vocab
    for c in range(min(max_chunk, base), 0, -1):
        if base % c == 0 and vocab % c == 0:
            return c if c > 64 else 0
    return 0


def cell_config(cfg: ModelConfig, shape_name: str, profile: str
                ) -> Tuple[ModelConfig, Dict[str, Any]]:
    """Returns (model config with profile overrides, extra step options)."""
    opts: Dict[str, Any] = {"grad_accum": 1}
    over: Dict[str, Any] = {}

    if profile == "baseline":
        over["remat_policy"] = "full"
        if shape_name == "train_4k":
            # naive attention fits at 4k with grad-accum; S² is sharded
            over["attention_impl"] = "naive"
            opts["grad_accum"] = 8
        elif shape_name == "prefill_32k":
            # a 32k² f32 score tensor can never be resident -> chunked even
            # in the baseline (documented in EXPERIMENTS.md §Dry-run)
            over["attention_impl"] = "chunked"
            over["attention_chunk"] = 2048
        else:
            over["attention_impl"] = "naive"
        return cfg.replace(**over), opts

    # ---- tuned profile (final choices from the §Perf iteration log) ----
    over["remat_policy"] = "full"
    if shape_name == "train_4k":
        # measured: at 4k with head-sharded scores, naive attention beats the
        # chunked scan on HBM traffic; SP doubles AR volume on these
        # collective-bound cells (§Perf C iterations 1-2) -> both off.
        over["attention_impl"] = "naive"
        over["sequence_parallel"] = False
        opts["grad_accum"] = 8
        if cfg.moe is not None and cfg.moe.n_experts:
            opts["grad_accum"] = 16      # MoE dispatch working-set fit
    else:
        # 32k+ sequences: S² scores can never be resident -> online-softmax
        # chunks; these cells are memory-dominant, where SP's sharded
        # residual saves win (§Perf A/dry-run table).
        over["attention_impl"] = "chunked"
        over["attention_chunk"] = 2048
        if shape_name == "prefill_32k":
            over["sequence_parallel"] = True
    if shape_name in ("train_4k", "prefill_32k"):
        # full-sequence recurrences: chunked WKV / log-depth SSM scan
        # (baseline keeps the paper-naive sequential scans: 44-250x — §Perf A)
        over["time_mix_impl"] = "chunked"
        over["ssm_impl"] = "associative"
    # Chunked logsumexp loss: measured NET-NEGATIVE at these shapes even for
    # non-16-divisible vocabs (replicated [T,V] logits fit comfortably at
    # 4k and the chunk scan adds weight re-reads) — granite train frac
    # 0.0490 dense vs 0.0467 chunked.  The lever stays available
    # (`vocab_loss_chunk`) for configs where logits don't fit; see §Perf.
    return cfg.replace(**over), opts


# ---------------------------------------------------------------------------
# Kernel autotuning seeds (support_count / rule_match tile spaces)
# ---------------------------------------------------------------------------

# VPU-flavored throughput for the packed popcount path: the AND + popcount
# + add word ops run on the vector unit, not the systolic array, at roughly
# an eighth of the MXU's MAC rate per the v5e datapath width.
VPU_OPS = PEAK_FLOPS / 8.0
# Ops per packed word-pair: AND, popcount, accumulate.
_PACKED_OPS_PER_WORD = 3.0
# Fixed cost per grid step (launch + block DMA setup): what makes small
# tiles expensive in the estimate, so the seed order prefers few launches
# until the working set forces tiling.
KERNEL_STEP_OVERHEAD_US = 15.0

TUNABLE_KERNELS = ("support_count", "intersect_count", "rule_match")


def _fit_tile(want: int, dim: int, floor: int = 1) -> int:
    """Largest power-of-two-shrunk tile <= want that divides dim."""
    t = max(floor, min(want, dim))
    while dim % t:
        t //= 2
    return max(t, 1)


def kernel_candidates(kernel: str, shape: Tuple[int, ...]
                      ) -> List[Dict[str, Any]]:
    """The swept config space for one kernel at one (padded) shape.

    support_count:   shape = (N, M, I) — transactions, candidates, items.
    intersect_count: shape = (M, W)    — candidate rows, packed tid words.
    rule_match:      shape = (B, R, I) — queries, rule rows, items.
    Every candidate is a dict with a ``variant`` plus that variant's tile
    shape; all candidates compute bit-identical results (the fuzz harness
    holds the tuner to that), so picking any of them is safe.
    """
    if kernel not in TUNABLE_KERNELS:
        raise ValueError(f"unknown tunable kernel {kernel!r} "
                         f"(known: {', '.join(TUNABLE_KERNELS)})")
    cands: List[Dict[str, Any]] = []
    seen = set()

    def add(cfg: Dict[str, Any]) -> None:
        key = tuple(sorted(cfg.items()))
        if key not in seen:
            seen.add(key)
            cands.append(cfg)

    if kernel == "intersect_count":
        # row-aligned AND-popcount: one variant (there is no matmul
        # formulation of a per-row intersection), tiles over (M, W) only
        m, w = shape
        for wm in (512, 256, 128, m):
            for ww in (512, 128, w):
                add({"variant": "packed", "bm": _fit_tile(wm, m),
                     "bw": _fit_tile(ww, w)})
        return cands

    n, m, i = shape
    a, b = ("bn", "bm") if kernel == "support_count" else ("bb", "br")
    for wn in (512, 256, n):
        for wm in (256, 128, m):
            add({"variant": "mxu", a: _fit_tile(wn, n), b: _fit_tile(wm, m),
                 "bi": _fit_tile(512, i)})
            add({"variant": "packed", a: _fit_tile(wn, n),
                 b: _fit_tile(wm, m)})
    return cands


def estimate_cost_us(kernel: str, shape: Tuple[int, ...],
                     config: Dict[str, Any]) -> float:
    """Roofline-seeded cost estimate (µs) for one candidate config.

    max(compute, HBM traffic) over the v5e constants plus a per-grid-step
    launch overhead; traffic counts the block re-reads tiling implies
    (T/Q re-read once per candidate tile, C/A once per row tile).
    """
    if kernel == "intersect_count":
        # both slabs read exactly once (row-aligned, no re-reads); the
        # [1, bm] out block is revisited once per word tile
        m, w = shape
        tm, tw = config["bm"], config["bw"]
        steps = (m // tm) * (w // tw)
        compute_s = _PACKED_OPS_PER_WORD * m * w / VPU_OPS
        traffic = 4.0 * (2.0 * m * w + m * (w // tw))
        return (max(compute_s, traffic / HBM_BW) * 1e6
                + steps * KERNEL_STEP_OVERHEAD_US)
    n, m, i = shape
    a, b = ("bn", "bm") if kernel == "support_count" else ("bb", "br")
    tn, tm = config[a], config[b]
    steps_n, steps_m = n // tn, m // tm
    if config["variant"] == "mxu":
        ti = config.get("bi", i)
        steps = steps_n * steps_m * (i // ti)
        compute_s = 2.0 * n * m * i / PEAK_FLOPS
        traffic = n * i * steps_m + m * i * steps_n + 4.0 * m * steps_n
    else:
        w = i / 32.0
        steps = steps_n * steps_m
        compute_s = _PACKED_OPS_PER_WORD * n * m * w / VPU_OPS
        traffic = 4.0 * (n * w * steps_m + m * w * steps_n + m * steps_n)
    return (max(compute_s, traffic / HBM_BW) * 1e6
            + steps * KERNEL_STEP_OVERHEAD_US)


def default_config(kernel: str, shape: Tuple[int, ...]) -> Dict[str, Any]:
    """Cold-cache fallback: argmin of the roofline estimates (no
    measurement, deterministic — ties broken by the candidate order)."""
    cands = kernel_candidates(kernel, shape)
    return min(cands, key=lambda c: (estimate_cost_us(kernel, shape, c),
                                     sorted(c.items()).__repr__()))


def seed_order(kernel: str, shape: Tuple[int, ...],
               cands: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Sweep order: cheapest estimate first, so a truncated (smoke) sweep
    still measures the configs the roofline model believes in."""
    return sorted(cands, key=lambda c: estimate_cost_us(kernel, shape, c))


def shape_flops_bytes(kernel: str, shape: Tuple[int, ...]
                      ) -> Tuple[float, float]:
    """Task-intrinsic (flops, bytes) for one kernel shape — the variant-
    independent work the containment test costs, used to turn a measured
    wall into effective peak/bandwidth for CostModelPolicy seeding."""
    if kernel == "intersect_count":
        # one AND+popcount+add per word-pair ≙ the 2·32 bit-ops the dense
        # formulation would spend on those 32 items (64 flops per word)
        m, w = shape
        return 64.0 * m * w, float(8 * m * w + 4 * m)
    n, m, i = shape
    flops = 2.0 * n * m * i
    bytes_ = float(n * i + m * i + 4 * m + (4 * n * m
                                            if kernel == "rule_match" else 0))
    return flops, bytes_
