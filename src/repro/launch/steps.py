"""Step builders (train / prefill / decode) + abstract input specs.

These are the functions the dry-run lowers and the launchers execute; they
are pure and closed over a hashable :class:`ModelConfig`, so one jit cache
entry serves every rank.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, grad_accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 scans over microbatches accumulating f32 gradients —
    the activation-memory lever for the big shapes (§Perf)."""

    def loss_fn(params, batch):
        return T.model_loss(params, cfg, batch)

    def train_step(params, opt_state: OptState, batch: Dict[str, jnp.ndarray]):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # Reshape (B, ...) -> (B/accum, accum, ...) then swap: the global
            # batch dim stays contiguous per data shard, so GSPMD keeps the
            # microbatch sharded on ("pod","data").  A direct
            # (accum, B/accum, ...) reshape interleaves shards and silently
            # REPLICATES activations (16x flops — found via the HLO cost
            # model; see EXPERIMENTS.md §Perf iteration 0).
            mbs = jax.tree.map(
                lambda x: x.reshape((x.shape[0] // grad_accum, grad_accum)
                                    + x.shape[1:]).swapaxes(0, 1), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (loss_sum + l, gsum), None

            (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), mbs)
            loss = loss_sum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, h = T.prefill(params, cfg, batch)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig, greedy: bool = True):
    def decode_one(params, cache, tokens, pos):
        logits, new_cache = T.decode_step(params, cfg, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return decode_one


# ---------------------------------------------------------------------------
# abstract input specs (MULTI-POD DRY-RUN §2)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the data batch of a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        return {"frames": _sds((B, S, cfg.d_model), jnp.bfloat16),
                "labels": _sds((B, S, cfg.n_codebooks), jnp.int32)}
    if cfg.frontend == "vision":
        return {"tokens": _sds((B, S), jnp.int32),
                "vision_embeds": _sds((B, cfg.n_vision_tokens, cfg.d_model),
                                      jnp.bfloat16)}
    return {"tokens": _sds((B, S), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for one decode step with a seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    if cfg.frontend == "audio":
        tokens = _sds((B, 1, cfg.n_codebooks), jnp.int32)
    else:
        tokens = _sds((B, 1), jnp.int32)
    return {"cache": cache, "tokens": tokens,
            "pos": _sds((), jnp.int32)}


def param_specs(cfg: ModelConfig, seed: int = 0) -> Any:
    return jax.eval_shape(
        functools.partial(T.init_params, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_opt_state(params_spec) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(f32, params_spec),
                    nu=jax.tree.map(f32, params_spec),
                    step=jax.ShapeDtypeStruct((), jnp.int32))


def input_specs(arch_or_cfg, shape_name: str) -> Dict[str, Any]:
    """Every model input for (arch, shape) as ShapeDtypeStructs."""
    from repro.configs.base import get_config
    cfg = arch_or_cfg if isinstance(arch_or_cfg, ModelConfig) \
        else get_config(arch_or_cfg)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return batch_specs(cfg, shape)
