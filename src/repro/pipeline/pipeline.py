"""MarketBasketPipeline — the paper end-to-end, as one object.

Composition (paper §V):

  baskets ──pack──▶ bitmap T[n_tx, n_items]
     │
     ├─ round k=1: item-frequency MapReduceJob (tiled over the profile)
     ├─ round k≥2: serial candidate generation  → MBScheduler.assign_serial
     │             (one core runs, the rest are power-gated)
     │             tiled support counting       → MBScheduler.assign_parallel
     │             (DataPlane: Pallas kernel on TPU, jitted ref elsewhere)
     ├─ rules: confidence/lift pruning, serial phase on the fastest core
     ▼
  PipelineResult(supports, rules, PipelineReport)

The control plane (candidate generation, rule enumeration) is host Python
— the paper's "single-threaded tasks"; its scheduling/energy is *modeled*
through the same MBScheduler/PowerModel the map phases use, so a run's
report answers the paper's questions: where did the time go, what did
gating save, what did core switching cost.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import (AprioriResult, frequent_itemsets,
                                 generate_candidates, itemsets_to_bitmap)
from repro.core.mapreduce import (ExecReport, FailureEvent, MapReduceJob,
                                  SimulatedCluster)
from repro.core.power import PowerModel
from repro.core.rules import Rule, generate_rules
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.data.baskets import pack_transactions, pad_items
from repro.pipeline.dataplane import DataPlane, uniform_tiles
from repro.pipeline.report import (PipelineReport, RoundReport, SerialPhase,
                                   busy_list)

Baskets = Union[np.ndarray, Sequence[Sequence[int]]]


def ingest_baskets(baskets: Baskets) -> Tuple[np.ndarray, int, int]:
    """Validate + pack baskets into the kernel bitmap layout.

    Returns ``(lane-padded bitmap, raw item count, raw tx count)``.  Shared
    by the single-device pipeline and the sharded miner so both planes agree
    byte-for-byte on what they mine.
    """
    if isinstance(baskets, np.ndarray):
        if baskets.ndim != 2:
            raise ValueError(f"bitmap must be 2-D, got {baskets.shape}")
        # validate BEFORE the uint8 cast: casting would truncate floats
        # (0.9 -> 0) and wrap negatives, hiding bad input behind an
        # empty-but-plausible mining result
        if baskets.size and not ((baskets == 0) | (baskets == 1)).all():
            raise ValueError("bitmap must contain only 0/1 — pass "
                             "transaction lists for count-style data")
        T = baskets.astype(np.uint8, copy=False)
    else:
        T = pack_transactions(baskets)
    return pad_items(T), T.shape[1], T.shape[0]


def model_serial_phase(scheduler: MBScheduler, power: Optional[PowerModel],
                       profile: HeterogeneityProfile, name: str, cost: float,
                       host_time_s: float,
                       device: Optional[int] = None) -> SerialPhase:
    """Model a single-threaded phase: one core runs, the rest gate off.

    `device` pins the core (the sharded plane routes driver phases to rank
    0); otherwise `assign_serial` picks the most capable one.
    """
    asg = scheduler.assign_serial(TaskSpec(name, cost, parallel=False),
                                  device=device)
    dev = asg.serial_device
    sim_t = float(asg.est_finish[dev])
    energy = 0.0
    if power is not None:
        busy = np.zeros(profile.n)
        busy[dev] = sim_t
        energy = power.energy(busy, sim_t, gated=asg.gated)
    return SerialPhase(name=name, device=dev, cost=cost, sim_time_s=sim_t,
                       host_time_s=host_time_s, energy_j=energy,
                       gated=list(asg.gated))


@dataclass(frozen=True)
class PipelineConfig:
    """Knobs for one mining run.  min_support <= 1 is a fraction of n_tx
    (1.0 = present in every transaction); values above 1 are absolute
    transaction counts."""

    min_support: float = 0.02
    min_confidence: float = 0.6
    min_lift: float = 0.0
    max_k: int = 0                  # 0 = mine until no candidates survive
    n_tiles: int = 32
    policy: str = "lpt"             # equal | proportional | lpt
    data_plane: str = "auto"        # auto | pallas | ref
    m_bucket: int = 128             # candidate-batch rounding (kernel lanes)
    interpret: Optional[bool] = None  # force Pallas interpret mode (tests)
    power: str = "cpu"              # cpu | tpu_v5e | none
    speculate: bool = True
    # Serial-phase cost model: work units charged per (itemset, level) pair
    # examined by the join/prune (same units as tile bytes, so serial and
    # map phases share one time axis).  Calibrated so candidate generation
    # is small-but-visible next to counting, as in the paper.
    serial_unit_cost: float = 64.0

    def abs_support(self, n_tx: int) -> int:
        if self.min_support <= 1.0:
            return max(1, int(self.min_support * n_tx))
        return int(self.min_support)


@dataclass
class PipelineResult:
    supports: Dict[Tuple[int, ...], int]
    rules: List[Rule]
    report: PipelineReport
    n_tx: int

    def frequent(self, k: Optional[int] = None) -> List[Tuple[int, ...]]:
        return frequent_itemsets(self.supports, k)


class MarketBasketPipeline:
    """Orchestrates the full mining run over a heterogeneity profile."""

    def __init__(self, profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[PipelineConfig] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None):
        self.profile = profile or HeterogeneityProfile.paper()
        self.config = config or PipelineConfig()
        self.scheduler = scheduler or MBScheduler(self.profile,
                                                  policy=self.config.policy)
        if power is not None:
            self.power = power
        elif self.config.power == "cpu":
            self.power = PowerModel.cpu(self.profile)
        elif self.config.power == "tpu_v5e":
            self.power = PowerModel.tpu_v5e(self.profile.n)
        elif self.config.power == "none":
            self.power = None
        else:
            raise ValueError(f"unknown power model {self.config.power!r}")
        self.cluster = SimulatedCluster(self.profile, self.scheduler,
                                        power=None)  # energy computed here
        self.data_plane = DataPlane(self.config.data_plane,
                                    m_bucket=self.config.m_bucket,
                                    interpret=self.config.interpret)

    # ------------------------------------------------------------------
    # phases
    # ------------------------------------------------------------------
    def _ingest(self, baskets: Baskets) -> Tuple[np.ndarray, int, int]:
        """Returns (lane-padded bitmap, raw item count, raw tx count)."""
        return ingest_baskets(baskets)

    def _serial_phase(self, name: str, cost: float,
                      host_time_s: float) -> SerialPhase:
        """Model a single-threaded phase: best core runs, the rest gate off."""
        return model_serial_phase(self.scheduler, self.power, self.profile,
                                  name, cost, host_time_s)

    def _map_round(self, job: MapReduceJob, tiles: List[np.ndarray],
                   failures: Optional[List[FailureEvent]]
                   ) -> Tuple[np.ndarray, ExecReport, float, int]:
        result, rep = self.cluster.run(job, tiles, failures=failures,
                                       speculate=self.config.speculate)
        switches = rep.switches            # per-run: this round's moves only
        energy = 0.0
        if self.power is not None:
            # gate by what actually ran, not the planned assignment: after a
            # failure re-plan a planned-empty core may have executed orphans
            # (must be billed active) and a dead core ran nothing (gated)
            gated = [d for d in range(self.profile.n)
                     if rep.busy_s[d] == 0.0]
            energy = self.power.energy(rep.busy_s, rep.makespan, gated=gated,
                                       switches=switches)
            # a core that died mid-round worked (active) then powered off:
            # convert its post-death idle tail to gated watts
            for d in rep.failed_devices:
                if rep.busy_s[d] > 0.0:
                    tail = max(rep.makespan - rep.busy_s[d], 0.0)
                    energy += (self.power.p_gated[d]
                               - self.power.p_idle[d]) * tail
        return result, rep, energy, switches

    # ------------------------------------------------------------------
    def run(self, baskets: Baskets,
            failures: Optional[List[FailureEvent]] = None) -> PipelineResult:
        cfg = self.config
        t_start = time.perf_counter()

        T, n_items_raw, n_tx_raw = self._ingest(baskets)
        n_tx, n_items = T.shape                     # lane-padded (internal)
        min_sup = cfg.abs_support(n_tx_raw)
        # device-resident once: every round's map phase reuses these tiles,
        # so uploading per round would redo the same host->device transfers
        tiles = [jnp.asarray(t) for t in uniform_tiles(T, cfg.n_tiles)]

        report = PipelineReport(
            backend=self.data_plane.backend, policy=self.scheduler.policy,
            profile_speeds=[float(s) for s in self.profile.speeds],
            n_tx=n_tx_raw, n_items=n_items_raw,
            n_tiles=len(tiles), min_support=min_sup)
        supports: Dict[Tuple[int, ...], int] = {}

        # ---- round k=1: item frequency (<item, count>) ----------------
        job1 = MapReduceJob(
            name="mba-round1-item-counts",
            # sum on device, transfer n_items ints — not the whole tile back
            map_fn=lambda tile: np.asarray(
                tile.sum(axis=0, dtype=jnp.int32), dtype=np.int64),
            combine_fn=lambda a, b: a + b,
            zero_fn=lambda: np.zeros(n_items, dtype=np.int64),
        )
        counts, rep, energy, switches = self._map_round(job1, tiles, failures)
        frequent = [(int(i),) for i in np.nonzero(counts >= min_sup)[0]]
        for (i,) in frequent:
            supports[(i,)] = int(counts[i])
        report.rounds.append(RoundReport(
            k=1, n_candidates=n_items_raw, n_frequent=len(frequent),
            n_tiles=len(tiles),
            tiles_per_device=_tile_histogram(rep),
            map_makespan_s=rep.makespan, map_busy_s=busy_list(rep.busy_s),
            switches=switches, reissued=rep.reissued, energy_j=energy,
            failed_devices=list(rep.failed_devices)))

        # ---- rounds k>=2: serial candidate-gen + tiled counting -------
        k = 2
        while frequent and (cfg.max_k == 0 or k <= cfg.max_k):
            t0 = time.perf_counter()
            cands = generate_candidates(frequent)
            host_t = time.perf_counter() - t0
            serial = self._serial_phase(
                f"mba-candgen-k{k}",
                cost=max(1.0, len(frequent) * k * cfg.serial_unit_cost),
                host_time_s=host_t)
            if not cands:
                report.rounds.append(RoundReport(
                    k=k, n_candidates=0, n_frequent=0, n_tiles=0,
                    tiles_per_device=[0] * self.profile.n,
                    map_makespan_s=0.0, map_busy_s=[0.0] * self.profile.n,
                    switches=0, reissued=0, energy_j=0.0, serial=serial))
                break

            self.data_plane.prepare(itemsets_to_bitmap(cands, n_items))
            job = MapReduceJob(
                name=f"mba-round{k}-support",
                map_fn=self.data_plane.tile_counts,
                combine_fn=lambda a, b: a + b,
                zero_fn=lambda m=len(cands): np.zeros(m, dtype=np.int64),
            )
            sup, rep, energy, switches = self._map_round(job, tiles, failures)
            frequent = []
            for c, s in zip(cands, sup):
                if s >= min_sup:
                    supports[c] = int(s)
                    frequent.append(c)
            report.rounds.append(RoundReport(
                k=k, n_candidates=len(cands), n_frequent=len(frequent),
                n_tiles=len(tiles),
                tiles_per_device=_tile_histogram(rep),
                map_makespan_s=rep.makespan, map_busy_s=busy_list(rep.busy_s),
                switches=switches, reissued=rep.reissued, energy_j=energy,
                serial=serial, m_padded=self.data_plane.m_padded,
                failed_devices=list(rep.failed_devices)))
            k += 1

        # ---- step 3: association rules (serial control plane) ---------
        t0 = time.perf_counter()
        rules = generate_rules(
            AprioriResult(supports=supports, n_tx=n_tx_raw, levels=k - 1),
            cfg.min_confidence, min_lift=cfg.min_lift)
        host_t = time.perf_counter() - t0
        report.rules_phase = self._serial_phase(
            "mba-rules",
            cost=max(1.0, len(supports) * cfg.serial_unit_cost),
            host_time_s=host_t)

        report.n_itemsets = len(supports)
        report.n_rules = len(rules)
        report.wall_time_s = time.perf_counter() - t_start
        return PipelineResult(supports=supports, rules=rules, report=report,
                              n_tx=n_tx_raw)


def _tile_histogram(rep: ExecReport) -> List[int]:
    """Tiles *executed* per device (orphans counted at the survivor that
    re-ran them after a failure).  Σ == n_tiles always."""
    assert rep.tiles_done is not None, "SimulatedCluster always sets this"
    return list(rep.tiles_done)
