"""MarketBasketPipeline — the paper end-to-end, as one object.

Composition (paper §V):

  baskets ──pack──▶ bitmap T[n_tx, n_items]
     │
     ├─ round k=1: item-frequency MapReduceJob (tiled over the profile)
     ├─ round k≥2: serial candidate generation  → Runtime.run_serial
     │             (one core runs, the rest are power-gated)
     │             tiled support counting       → Runtime.run_phase
     │             (DataPlane: Pallas kernel on TPU, jitted ref elsewhere)
     ├─ rules: confidence/lift pruning, serial phase on the fastest core
     ▼
  PipelineResult(supports, rules, PipelineReport)

The control plane (candidate generation, rule enumeration) is host Python
— the paper's "single-threaded tasks"; its scheduling/energy is *modeled*
through the shared :class:`repro.runtime.Runtime`, which owns the
MBScheduler + PowerModel + phase ledger and performs assignment, policy
feedback and accounting exactly once per phase.  The switching policy
(``static`` | ``dynamic`` | ``costmodel``) is a config knob; execution
stays in :class:`SimulatedCluster`, which honors whatever assignment the
policy planned.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import (AprioriResult, frequent_itemsets,
                                 generate_candidates, itemsets_to_bitmap)
from repro.core.mapreduce import FailureEvent, MapReduceJob, SimulatedCluster
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.core.rules import Rule, generate_rules
from repro.data.baskets import pack_transactions, pad_items
from repro.data.sparse import SparseSlab
from repro.pipeline.dataplane import DataPlane, uniform_tiles
from repro.pipeline.devgen import DeviceLattice
from repro.pipeline.report import PipelineReport, RoundReport
from repro.runtime import (MeasuredPhase, Runtime, SlabPool, SwitchingPolicy,
                           autotuned_costmodel, donated_add)

Baskets = Union[np.ndarray, SparseSlab, Sequence[Sequence[int]]]


def ingest_baskets(baskets: Baskets) -> Tuple[np.ndarray, int, int]:
    """Validate + pack baskets into the kernel bitmap layout.

    Returns ``(lane-padded bitmap, raw item count, raw tx count)``.  Shared
    by the single-device pipeline and the sharded miner so both planes agree
    byte-for-byte on what they mine.  A :class:`SparseSlab` densifies here
    *explicitly* — the horizontal (Apriori) formulation needs the dense
    bitmap; the Eclat plane columnizes the slab without it.
    """
    if isinstance(baskets, SparseSlab):
        baskets = baskets.to_dense()
    if isinstance(baskets, np.ndarray):
        if baskets.ndim != 2:
            raise ValueError(f"bitmap must be 2-D, got {baskets.shape}")
        # validate BEFORE the uint8 cast: casting would truncate floats
        # (0.9 -> 0) and wrap negatives, hiding bad input behind an
        # empty-but-plausible mining result
        if baskets.size and not ((baskets == 0) | (baskets == 1)).all():
            raise ValueError("bitmap must contain only 0/1 — pass "
                             "transaction lists for count-style data")
        T = baskets.astype(np.uint8, copy=False)
    else:
        T = pack_transactions(baskets)
    return pad_items(T), T.shape[1], T.shape[0]


@dataclass(frozen=True)
class PipelineConfig:
    """Knobs for one mining run.  min_support <= 1 is a fraction of n_tx
    (1.0 = present in every transaction); values above 1 are absolute
    transaction counts."""

    min_support: float = 0.02
    min_confidence: float = 0.6
    min_lift: float = 0.0
    max_k: int = 0                  # 0 = mine until no candidates survive
    # Mining backend: "apriori" (horizontal bitmap rounds), "eclat"
    # (vertical tid-list intersections), or "auto" (the algorithm cost
    # model picks per dataset from measured density/sparsity features —
    # see repro.mining.select).  All backends are pinned bit-identical.
    algorithm: str = "apriori"
    # Round execution: "pipelined" (default) dispatches every tile kernel
    # eagerly, folds partial counts into a donated device accumulator and
    # reads back one packed vector per round (single sync point; candidate
    # generation stays on device — see repro.pipeline.devgen).  "per_tile"
    # is the legacy sync-per-tile path, kept as the B13 A/B baseline.
    round_execution: str = "pipelined"
    n_tiles: int = 32
    policy: str = "static"          # switching: static | dynamic | costmodel
    split: str = "lpt"              # tile split: equal | proportional | lpt
    data_plane: str = "auto"        # auto | pallas | ref
    m_bucket: int = 128             # candidate-batch rounding (kernel lanes)
    interpret: Optional[bool] = None  # force Pallas interpret mode (tests)
    # Kernel autotuning: True = the checked-in winner cache picks the
    # Pallas variant + tile shapes (and, under the costmodel policy, its
    # measured walls replace the datasheet roofline constants); False =
    # roofline-seeded defaults everywhere.
    autotune: bool = True
    power: str = "cpu"              # cpu | tpu_v5e | none
    speculate: bool = True
    # Serial-phase cost model: work units charged per (itemset, level) pair
    # examined by the join/prune (same units as tile bytes, so serial and
    # map phases share one time axis).  Calibrated so candidate generation
    # is small-but-visible next to counting, as in the paper.
    serial_unit_cost: float = 64.0
    # Required core speed for serial phases: when no core satisfies it,
    # assign_serial falls back to the fastest core and flags the phase
    # (surfaced as PipelineReport.constraint_violations, never silent).
    serial_min_speed: float = 0.0

    def abs_support(self, n_tx: int) -> int:
        if self.min_support <= 1.0:
            return max(1, int(self.min_support * n_tx))
        return int(self.min_support)


def candgen_cost(n_frequent: int, k: int, unit_cost: float) -> float:
    """Work units for the serial F_{k-1}⋈F_{k-1} join/prune phase.

    Shared by the batch pipeline and the streaming plane's re-validation
    pass — the two Apriori drivers must price (and therefore schedule)
    identical rounds identically, or their ledgers drift."""
    return max(1.0, n_frequent * k * unit_cost)


def support_flops(tile_rows: np.ndarray, n_items: int,
                  m_padded: int) -> np.ndarray:
    """Roofline seed for a support-count map phase: the kernel's MXU work
    is 2·rows·items·candidates per tile (bytes are rows·items).  Shared
    across the Apriori drivers for the same reason as candgen_cost."""
    return 2.0 * tile_rows * n_items * max(m_padded, 1)


@dataclass
class PipelineResult:
    supports: Dict[Tuple[int, ...], int]
    rules: List[Rule]
    report: PipelineReport
    n_tx: int

    def frequent(self, k: Optional[int] = None) -> List[Tuple[int, ...]]:
        return frequent_itemsets(self.supports, k)


class MarketBasketPipeline:
    """Orchestrates the full mining run over a heterogeneity profile."""

    def __init__(self, profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[PipelineConfig] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None,
                 policy: Union[str, SwitchingPolicy, None] = None):
        self.profile = profile or HeterogeneityProfile.paper()
        self.config = config or PipelineConfig()
        cfg = self.config
        policy = policy if policy is not None else cfg.policy
        if policy == "costmodel" and cfg.autotune:
            # measured kernel walls replace the datasheet constants
            policy = autotuned_costmodel("support_count")
        self.runtime = Runtime(
            self.profile,
            policy=policy,
            split=cfg.split,
            power=power if power is not None else cfg.power,
            scheduler=scheduler)
        self.scheduler = self.runtime.scheduler
        self.power = self.runtime.power
        self.cluster = SimulatedCluster(self.profile, self.scheduler,
                                        power=None)  # ledger prices energy
        if cfg.round_execution not in ("pipelined", "per_tile"):
            raise ValueError(
                f"unknown round_execution {cfg.round_execution!r} "
                "(expected 'pipelined' or 'per_tile')")
        self.data_plane = DataPlane(cfg.data_plane,
                                    m_bucket=cfg.m_bucket,
                                    interpret=cfg.interpret,
                                    tuning=None if cfg.autotune else False,
                                    meter=self.runtime.meter)
        # round-persistent donated count accumulators, keyed by bucket shape
        self.slabs = SlabPool()

    # ------------------------------------------------------------------
    # phases
    # ------------------------------------------------------------------
    def _ingest(self, baskets: Baskets) -> Tuple[np.ndarray, int, int]:
        """Returns (lane-padded bitmap, raw item count, raw tx count)."""
        return ingest_baskets(baskets)

    def _map_round(self, job: MapReduceJob, tiles: List,
                   failures: Optional[List[FailureEvent]],
                   tile_flops: Optional[np.ndarray] = None,
                   finalize=None):
        """One tiled map phase through the shared runtime: the policy plans
        the assignment, the simulated cluster executes it, the runtime does
        the time/energy/switch accounting exactly once.  ``finalize`` runs
        on the combined result *inside* the phase — the pipelined path's
        single d2h readback happens there, so the sync lands on this
        phase's ledger record, not the next one's."""
        tile_costs = np.array([job.tile_cost(t) for t in tiles],
                              dtype=np.float64)
        # one family: every round maps the same device-resident tiles, so
        # dynamic switching tracks owner drift across rounds
        task = TaskSpec(job.name, float(tile_costs.sum()), parallel=True,
                        n_tiles=len(tiles), family="mba-map")

        def execute(asg, _costs):
            result, rep = self.cluster.run(job, tiles, failures=failures,
                                           speculate=self.config.speculate,
                                           assignment=asg)
            if finalize is not None:
                result = finalize(result)
            return MeasuredPhase(result=result, busy_s=rep.busy_s,
                                 makespan=rep.makespan,
                                 switches=rep.switches, reissued=rep.reissued,
                                 failed_devices=list(rep.failed_devices),
                                 tiles_done=rep.tiles_done)

        return self.runtime.run_phase(task, execute, tile_costs=tile_costs,
                                      tile_flops=tile_flops)

    # ------------------------------------------------------------------
    def run(self, baskets: Baskets,
            failures: Optional[List[FailureEvent]] = None) -> PipelineResult:
        if self.config.round_execution == "pipelined":
            return self._run_pipelined(baskets, failures)
        return self._run_per_tile(baskets, failures)

    # ------------------------------------------------------------------
    # legacy sync-per-tile rounds — the B13 A/B baseline
    # ------------------------------------------------------------------
    def _run_per_tile(self, baskets: Baskets,
                      failures: Optional[List[FailureEvent]] = None
                      ) -> PipelineResult:
        cfg = self.config
        rt = self.runtime
        t_start = time.perf_counter()
        # a run that raised mid-way (invariant check, scoring error) leaves
        # orphaned records; this plane owns its runtime, so anything still
        # live belongs to no report — drop it before marking
        rt.ledger.take_since(0)
        mark = rt.ledger.mark()

        T, n_items_raw, n_tx_raw = self._ingest(baskets)
        n_tx, n_items = T.shape                     # lane-padded (internal)
        min_sup = cfg.abs_support(n_tx_raw)
        # device-resident once: every round's map phase reuses these tiles,
        # so uploading per round would redo the same host->device transfers
        tiles = [rt.meter.h2d(t) for t in uniform_tiles(T, cfg.n_tiles)]
        tile_rows = np.array([t.shape[0] for t in tiles], dtype=np.float64)

        report = PipelineReport(
            backend=self.data_plane.backend, policy=rt.policy.name,
            split=rt.split,
            profile_speeds=[float(s) for s in self.profile.speeds],
            n_tx=n_tx_raw, n_items=n_items_raw,
            n_tiles=len(tiles), min_support=min_sup)
        supports: Dict[Tuple[int, ...], int] = {}

        # ---- round k=1: item frequency (<item, count>) ----------------
        job1 = MapReduceJob(
            name="mba-round1-item-counts",
            # sum on device, transfer n_items ints — not the whole tile back
            # (still one readback *per tile*: that sync is this path's
            # defining cost, which the pipelined path removes)
            map_fn=lambda tile: rt.meter.d2h(
                tile.sum(axis=0, dtype=jnp.int32), dtype=np.int64),
            combine_fn=lambda a, b: a + b,
            zero_fn=lambda: np.zeros(n_items, dtype=np.int64),
        )
        counts, rec = self._map_round(job1, tiles, failures,
                                      tile_flops=tile_rows * n_items)
        frequent = [(int(i),) for i in np.nonzero(counts >= min_sup)[0]]
        for (i,) in frequent:
            supports[(i,)] = int(counts[i])
        report.rounds.append(RoundReport.from_phases(
            k=1, n_candidates=n_items_raw, n_frequent=len(frequent),
            map_phase=rec))

        # ---- rounds k>=2: serial candidate-gen + tiled counting -------
        k = 2
        while frequent and (cfg.max_k == 0 or k <= cfg.max_k):
            cands, serial = rt.run_serial(
                f"mba-candgen-k{k}",
                cost=candgen_cost(len(frequent), k, cfg.serial_unit_cost),
                fn=lambda fr=frequent: generate_candidates(fr),
                min_speed=cfg.serial_min_speed)
            if not cands:
                report.rounds.append(RoundReport.from_phases(
                    k=k, n_candidates=0, n_frequent=0, map_phase=None,
                    serial=serial, n_devices=self.profile.n))
                break

            self.data_plane.prepare(itemsets_to_bitmap(cands, n_items))
            job = MapReduceJob(
                name=f"mba-round{k}-support",
                map_fn=self.data_plane.tile_counts,
                combine_fn=lambda a, b: a + b,
                zero_fn=lambda m=len(cands): np.zeros(m, dtype=np.int64),
            )
            m_padded = self.data_plane.m_padded
            sup, rec = self._map_round(
                job, tiles, failures,
                tile_flops=support_flops(tile_rows, n_items, m_padded))
            frequent = []
            for c, s in zip(cands, sup):
                if s >= min_sup:
                    supports[c] = int(s)
                    frequent.append(c)
            report.rounds.append(RoundReport.from_phases(
                k=k, n_candidates=len(cands), n_frequent=len(frequent),
                map_phase=rec, serial=serial, m_padded=m_padded))
            k += 1

        # ---- step 3: association rules (serial control plane) ---------
        rules, rules_rec = rt.run_serial(
            "mba-rules",
            cost=max(1.0, len(supports) * cfg.serial_unit_cost),
            fn=lambda: generate_rules(
                AprioriResult(supports=supports, n_tx=n_tx_raw, levels=k - 1),
                cfg.min_confidence, min_lift=cfg.min_lift),
            min_speed=cfg.serial_min_speed)
        report.rules_phase = rules_rec

        report.n_itemsets = len(supports)
        report.n_rules = len(rules)
        report.wall_time_s = time.perf_counter() - t_start
        report.ledger = rt.ledger.take_since(mark)
        return PipelineResult(supports=supports, rules=rules, report=report,
                              n_tx=n_tx_raw)

    # ------------------------------------------------------------------
    # pipelined device-resident rounds (the default)
    # ------------------------------------------------------------------
    def _run_pipelined(self, baskets: Baskets,
                       failures: Optional[List[FailureEvent]] = None
                       ) -> PipelineResult:
        """Same mining semantics as :meth:`_run_per_tile`, with rounds held
        on device: all tile kernels of a round dispatch eagerly (nothing in
        the map fan-out synchronizes), partial counts fold into a donated
        slab accumulator, candidate generation for the next level runs as a
        jitted join on the compacted frequent matrix, and the only
        device→host crossing per counting round is one packed
        ``[m_cap + 1]`` vector (counts + next join size) read inside the
        map phase.  Itemset tuples reach the host once, at rule time."""
        cfg = self.config
        rt = self.runtime
        t_start = time.perf_counter()
        rt.ledger.take_since(0)
        mark = rt.ledger.mark()

        T, n_items_raw, n_tx_raw = self._ingest(baskets)
        n_tx, n_items = T.shape                     # lane-padded (internal)
        min_sup = cfg.abs_support(n_tx_raw)
        tiles = [rt.meter.h2d(t) for t in uniform_tiles(T, cfg.n_tiles)]
        tile_rows = np.array([t.shape[0] for t in tiles], dtype=np.float64)

        report = PipelineReport(
            backend=self.data_plane.backend, policy=rt.policy.name,
            split=rt.split,
            profile_speeds=[float(s) for s in self.profile.speeds],
            n_tx=n_tx_raw, n_items=n_items_raw,
            n_tiles=len(tiles), min_support=min_sup)
        supports: Dict[Tuple[int, ...], int] = {}
        lattice = DeviceLattice(n_items, m_bucket=cfg.m_bucket,
                                meter=rt.meter)

        # ---- round k=1: item frequency, one readback ------------------
        job1 = MapReduceJob(
            name="mba-round1-item-counts",
            map_fn=lambda tile: tile.sum(axis=0, dtype=jnp.int32),
            combine_fn=donated_add,
            zero_fn=lambda: jnp.zeros(n_items, jnp.int32),
        )
        counts, rec = self._map_round(
            job1, tiles, failures, tile_flops=tile_rows * n_items,
            finalize=lambda acc: rt.meter.d2h(acc, dtype=np.int64))
        frequent_items = np.nonzero(counts >= min_sup)[0]
        for i in frequent_items:
            supports[(int(i),)] = int(counts[i])
        report.rounds.append(RoundReport.from_phases(
            k=1, n_candidates=n_items_raw, n_frequent=len(frequent_items),
            map_phase=rec))
        f_count = len(frequent_items)
        if f_count:
            # seeded between phases, so the (tiny) upload is attributed to
            # the phase that consumes it — the k=2 candgen
            lattice.seed_items(frequent_items)

        # ---- rounds k>=2: device candgen + device-combined counting ---
        k = 2
        while f_count and (cfg.max_k == 0 or k <= cfg.max_k):
            gen, serial = rt.run_serial(
                f"mba-candgen-k{k}",
                cost=candgen_cost(f_count, k, cfg.serial_unit_cost),
                fn=lattice.join,
                min_speed=cfg.serial_min_speed)
            if gen is None:
                report.rounds.append(RoundReport.from_phases(
                    k=k, n_candidates=0, n_frequent=0, map_phase=None,
                    serial=serial, n_devices=self.profile.n))
                break
            C, valid_c, bitmap, m_cap = gen
            self.data_plane.prepare_device(bitmap)
            job = MapReduceJob(
                name=f"mba-round{k}-support",
                map_fn=self.data_plane.tile_counts_device,
                combine_fn=donated_add,
                zero_fn=lambda m=m_cap: self.slabs.take((m,), jnp.int32),
            )

            def finalize(acc, C=C, valid_c=valid_c):
                packed, Fn, vn = lattice.finalize(acc, C, valid_c, min_sup)
                host = rt.meter.d2h(packed)    # the round's single sync
                self.slabs.give(acc)           # accumulator back to the pool
                return host, Fn, vn

            (packed, Fn, vn), rec = self._map_round(
                job, tiles, failures,
                tile_flops=support_flops(tile_rows, n_items, m_cap),
                finalize=finalize)
            m_true, f_count = lattice.advance(packed, Fn, vn, min_sup)
            report.rounds.append(RoundReport.from_phases(
                k=k, n_candidates=m_true, n_frequent=f_count,
                map_phase=rec, serial=serial, m_padded=m_cap))
            k += 1

        # ---- step 3: rules — tuples decode here, once -----------------
        n_supports = len(supports) + lattice.n_frequent_total

        def rules_fn():
            supports.update(lattice.decode_supports())
            return generate_rules(
                AprioriResult(supports=supports, n_tx=n_tx_raw,
                              levels=k - 1),
                cfg.min_confidence, min_lift=cfg.min_lift)

        rules, rules_rec = rt.run_serial(
            "mba-rules",
            cost=max(1.0, n_supports * cfg.serial_unit_cost),
            fn=rules_fn,
            min_speed=cfg.serial_min_speed)
        report.rules_phase = rules_rec

        report.n_itemsets = len(supports)
        report.n_rules = len(rules)
        report.wall_time_s = time.perf_counter() - t_start
        report.ledger = rt.ledger.take_since(mark)
        return PipelineResult(supports=supports, rules=rules, report=report,
                              n_tx=n_tx_raw)
