"""End-to-end market-basket pipeline (paper §V composed as one object)."""
from repro.pipeline.dataplane import DataPlane, resolve_backend, uniform_tiles
from repro.pipeline.pipeline import (MarketBasketPipeline, PipelineConfig,
                                     PipelineResult)
from repro.pipeline.report import (PipelineReport, RoundReport, SerialPhase)

__all__ = [
    "DataPlane", "MarketBasketPipeline", "PipelineConfig", "PipelineReport",
    "PipelineResult", "RoundReport", "SerialPhase", "resolve_backend",
    "uniform_tiles",
]
