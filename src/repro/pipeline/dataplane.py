"""Data plane for the pipeline: candidate support counting with stable shapes.

The paper's hot spot (Apriori step 2) runs on one of two backends:

* ``pallas`` — the MXU kernel in :mod:`repro.kernels.support_count` (the
  default on TPU; forced elsewhere it runs in interpret mode, which is only
  useful for tests).
* ``ref`` — the jitted pure-jnp oracle (the automatic off-TPU fallback).

Shape discipline is what makes either backend cheap across Apriori levels:
XLA recompiles per distinct input shape, so the pipeline (a) splits the
transaction bitmap into *uniform* row tiles and (b) pads every level's
candidate matrix up to a multiple of ``m_bucket`` rows.  Levels whose
candidate counts land in the same bucket then hit the same jit-cache entry
— one compiled kernel serves the whole mining run.

Padded candidate rows are all-zero; an all-zero mask would match every
transaction (``dot == |c| == 0``), so counts are always sliced back to the
true candidate count rather than trusting zeros.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.support_count.ops import support_count as _pallas_count
from repro.kernels.support_count.ref import support_count_ref as _ref_count
from repro.runtime.transfers import METER, TransferMeter

_jitted_ref = jax.jit(_ref_count)


def resolve_backend(kind: str = "auto") -> str:
    """'auto' → pallas on TPU, ref elsewhere; 'pallas'/'ref' force."""
    if kind == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if kind not in ("pallas", "ref"):
        raise ValueError(f"unknown data plane {kind!r}")
    return kind


def pad_candidates(C: np.ndarray, m_bucket: int) -> np.ndarray:
    """Pad the candidate axis up to a multiple of m_bucket with zero rows."""
    m = C.shape[0]
    pad = (-m) % m_bucket
    if pad == 0:
        return C
    return np.pad(C, ((0, pad), (0, 0)))


def uniform_tiles(T: np.ndarray, n_tiles: int,
                  row_multiple: int = 8) -> List[np.ndarray]:
    """Split T into n_tiles row tiles of identical shape (zero-row padded).

    Identical tile shapes are a jit-cache requirement, and all-zero padding
    rows are inert: they contain no items, so they can only support the
    empty itemset, which Apriori never emits (k >= 1).
    """
    n_tx = T.shape[0]
    n_tiles = max(1, min(n_tiles, n_tx))
    rows = -(-n_tx // n_tiles)                    # ceil
    rows += (-rows) % row_multiple                # kernel sublane alignment
    padded = np.pad(T, ((0, rows * n_tiles - n_tx), (0, 0)))
    return [np.ascontiguousarray(padded[i * rows:(i + 1) * rows])
            for i in range(n_tiles)]


class DataPlane:
    """Per-level candidate batch + per-tile support counting.

    Usage: ``prepare(C)`` once per Apriori level, then ``tile_counts(tile)``
    for every transaction tile (this is the MapReduceJob's map_fn).
    """

    def __init__(self, kind: str = "auto", m_bucket: int = 128,
                 interpret: Optional[bool] = None, tuning=None,
                 meter: Optional[TransferMeter] = None):
        if m_bucket <= 0 or m_bucket % 128:
            raise ValueError(
                "m_bucket must be a positive multiple of 128 (kernel lanes)")
        self.backend = resolve_backend(kind)
        self.m_bucket = m_bucket
        self.interpret = interpret
        # None = the checked-in autotune cache picks variant + tiles;
        # False = roofline defaults; dict/AutotuneCache pin the choice
        self.tuning = tuning
        # all boundary crossings this plane makes are metered, so the
        # owning Runtime's ledger can attribute them per phase
        self.meter = meter if meter is not None else METER
        self._C: Optional[jnp.ndarray] = None
        self._m_true = 0

    @property
    def m_padded(self) -> int:
        return int(self._C.shape[0]) if self._C is not None else 0

    # ------------------------------------------------------------------
    def prepare(self, C: np.ndarray) -> None:
        """Stage a level's candidate bitmap (padded to the bucket shape)."""
        self._m_true = C.shape[0]
        self._C = self.meter.h2d(pad_candidates(C, self.m_bucket))

    def prepare_device(self, C: jnp.ndarray) -> None:
        """Stage an already-device-resident candidate bitmap (the
        pipelined path: padding rows are zeroed, so no re-pad and no
        transfer — the generator built it in place)."""
        if C.shape[0] % self.m_bucket:
            raise ValueError(
                f"device candidate bitmap rows {C.shape[0]} not a multiple "
                f"of m_bucket={self.m_bucket}")
        self._m_true = int(C.shape[0])
        self._C = C

    def _counts(self, tile) -> jnp.ndarray:
        Tj = self.meter.h2d(tile)
        if self.backend == "pallas":
            return _pallas_count(Tj, self._C, interpret=self.interpret,
                                 tuning=self.tuning)
        return _jitted_ref(Tj, self._C)

    def tile_counts(self, tile: np.ndarray) -> np.ndarray:
        """Support counts [m_true] int64 for one transaction tile.

        The per-tile readback is a device sync: launches serialize on it,
        which is exactly what ``round_execution="per_tile"`` measures.
        """
        assert self._C is not None, "prepare() before tile_counts()"
        return self.meter.d2h(self._counts(tile)[:self._m_true],
                              dtype=np.int64)

    def tile_counts_device(self, tile) -> jnp.ndarray:
        """Device-resident counts [m_padded] int32 for one tile — no slice,
        no readback, no sync: the pipelined round combines these on device
        and reads one packed vector back at round close."""
        assert self._C is not None, "prepare() before tile_counts_device()"
        return self._counts(tile)
