"""On-device candidate generation — the Apriori lattice without host tuples.

The classic control plane decodes every level's frequent itemsets to host
tuples, runs the F_{k-1}⋈F_{k-1} join/prune in Python, re-packs the result
with ``itemsets_to_bitmap`` and uploads it — a d2h + h2d round-trip per
level that serializes the mining loop.  :class:`DeviceLattice` keeps the
frequent-set matrix device-resident instead and runs the join, the
downward-closure prune and the frequent-set compaction as two jitted
functions, so a round's only host crossing is one packed count vector:

  ``join()``      F[f_cap, k] ──prefix-join + prune──▶ C[m_cap, k+1] +
                  candidate bitmap (all device; no transfer)
  ``_finalize``   count accumulator ──▶ packed [m_cap+1] int32 vector:
                  per-candidate counts (−1 sentinel for padding) and J,
                  the next level's join-pair count — the **one d2h** the
                  pipelined round makes
  ``advance()``   host bookkeeping off the packed vector; the compacted
                  frequent matrix stays on device for the next join

Host code sees itemset *tuples* exactly once, in ``decode_supports()`` at
rule-generation time.

Correctness relies on an order invariant: the frequent matrix is kept
lexicographically sorted (valid rows first), and the join enumerates pairs
(i, j), i < j, in row-major order — which emits candidates in exactly the
sorted order the host ``generate_candidates`` returns, so count vectors
line up positionally with the reference path.  The prune checks dropped
positions 0..k−2 only: dropping position k−1 or k yields the two join
parents, frequent by construction — identical semantics to checking all
subsets.  Subset membership tests encode each (k−1)-subset as a base-
``n_items`` polynomial key and binary-search the frequent keys; levels
whose keys would overflow int32 (or whose frequent set outgrows the
quadratic join mask) fall back to the host join, metered.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.itemsets import generate_candidates, itemsets_to_bitmap
from repro.pipeline.dataplane import pad_candidates
from repro.runtime.transfers import METER, TransferMeter

# padding rows sort after every real key; valid keys stay below it because
# the device join is gated on n_items**(k-1) < _INVALID_KEY.  int32: x64
# is disabled in this deployment, so wider keys would silently truncate —
# levels whose keys need more than 31 bits take the host fallback instead.
_INVALID_KEY = np.int32(np.iinfo(np.int32).max)


def _encode(rows: jnp.ndarray, base: int) -> jnp.ndarray:
    """Lexicographic-order-preserving int32 key per row (fixed length)."""
    key = jnp.zeros(rows.shape[0], jnp.int32)
    for i in range(rows.shape[1]):
        key = key * base + rows[:, i].astype(jnp.int32)
    return key


@partial(jax.jit, static_argnames=("m_cap", "n_items"))
def _join_prune(F: jnp.ndarray, valid: jnp.ndarray, *,
                m_cap: int, n_items: int):
    """F_{k-1}⋈F_{k-1} join + downward-closure prune, all on device.

    F: [f_cap, k] int32 lexicographically sorted, valid rows first.
    Returns (C [m_cap, k+1] int32 compacted sorted candidates,
    valid_c [m_cap] bool, bitmap [m_cap, n_items] uint8).
    """
    f_cap, km1 = F.shape
    kc = km1 + 1
    # join: equal (k-1)-prefix, i < j — over empty prefixes (k=1) every
    # ordered pair of frequent items joins, as in the host path
    prefix_eq = jnp.all(F[:, None, :-1] == F[None, :, :-1], axis=-1)
    rows = jnp.arange(f_cap, dtype=jnp.int32)
    pair_ok = (prefix_eq & (rows[:, None] < rows[None, :])
               & valid[:, None] & valid[None, :])
    flat = pair_ok.reshape(-1)
    n_join = flat.sum()
    # compact the surviving pair indices to the front of an [m_cap] slot
    # array (m_cap = bucketed J is exact, so nothing ever drops)
    dest = jnp.cumsum(flat) - 1
    p = jnp.arange(f_cap * f_cap, dtype=jnp.int32)
    pair_idx = (jnp.zeros((m_cap,), jnp.int32)
                .at[jnp.where(flat, dest, m_cap)].set(p, mode="drop"))
    ii, jj = pair_idx // f_cap, pair_idx % f_cap
    C = jnp.concatenate([F[ii], F[jj][:, -1:]], axis=1)     # [m_cap, kc]
    valid_c = jnp.arange(m_cap) < n_join

    if kc > 2:
        fkeys = jnp.where(valid, _encode(F, n_items), _INVALID_KEY)
        for d in range(kc - 2):          # positions kc-2, kc-1 are parents
            sub = jnp.concatenate([C[:, :d], C[:, d + 1:]], axis=1)
            skey = _encode(sub, n_items)
            pos = jnp.clip(jnp.searchsorted(fkeys, skey), 0, f_cap - 1)
            valid_c = valid_c & (fkeys[pos] == skey)
        # re-compact: the host path drops pruned candidates, so survivors
        # must be contiguous (stable sort keeps them in sorted order)
        order = jnp.argsort(~valid_c, stable=True)
        C, valid_c = C[order], valid_c[order]

    hit = jnp.any(C[:, :, None]
                  == jnp.arange(n_items, dtype=C.dtype)[None, None, :],
                  axis=1)
    bitmap = (hit & valid_c[:, None]).astype(jnp.uint8)
    return C, valid_c, bitmap


@jax.jit
def _finalize(acc: jnp.ndarray, C: jnp.ndarray, valid_c: jnp.ndarray,
              min_sup) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Close a counting round on device: sentinel the padding counts,
    compact the frequent rows to the front (next level's F) and compute J,
    the next join's pair count, so the host can size round k+1 without a
    second readback.  Returns (packed [m_cap+1] int32, Fn, valid_n)."""
    counts = jnp.where(valid_c, acc.astype(jnp.int32), -1)
    freq = counts >= min_sup            # sentinel −1 < min_sup (>= 1)
    order = jnp.argsort(~freq, stable=True)
    Fn, vn = C[order], freq[order]
    # J = Σ_g s_g·(s_g−1)/2 over equal-prefix runs of the frequent rows;
    # padding rows sit compacted at the end with zero weight, so a run
    # they extend never changes size
    pre = Fn[:, :-1]
    newgrp = jnp.concatenate([jnp.ones((1,), bool),
                              jnp.any(pre[1:] != pre[:-1], axis=-1)])
    gid = jnp.cumsum(newgrp) - 1
    # a group's frequent rows share a prefix and differ in the last item,
    # so s <= n_items and the pair arithmetic stays well inside int32
    sizes = (jnp.zeros((Fn.shape[0],), jnp.int32)
             .at[gid].add(vn.astype(jnp.int32)))
    J = (sizes * (sizes - 1) // 2).sum().astype(jnp.int32)
    packed = jnp.concatenate([counts, J[None]])
    return packed, Fn, vn


@dataclass
class _Level:
    """One mined level kept for rule-time decode: the device matrix with
    frequent rows first, how many are real, and their (host) counts."""
    F: jnp.ndarray
    f_true: int
    counts: np.ndarray


class DeviceLattice:
    """Device-resident frequent-itemset state across Apriori levels.

    Protocol per level k >= 2 (driven by the pipeline):

    1. ``join()`` in the serial candgen phase — returns ``(C, valid_c,
       bitmap, m_cap)`` on device, or ``None`` when no pairs join (the
       round is dry, as when the host join returns ``[]``).
    2. the map phase folds tile counts into a device accumulator, then
       calls ``finalize``; its packed vector is the round's single d2h.
    3. ``advance()`` — host bookkeeping; frequent rows stay on device.
    4. after the loop, ``decode_supports()`` reads each level's frequent
       matrix back once for rule generation.
    """

    def __init__(self, n_items: int, m_bucket: int = 128,
                 meter: Optional[TransferMeter] = None,
                 max_join_rows: int = 4096,
                 max_candidates: int = 1 << 17):
        self.n_items = n_items
        self.m_bucket = m_bucket
        self.meter = meter if meter is not None else METER
        self.max_join_rows = max_join_rows
        self.max_candidates = max_candidates
        self.F: Optional[jnp.ndarray] = None       # [f_cap, k] int32
        self.valid: Optional[jnp.ndarray] = None   # [f_cap] bool
        self.k = 0
        self.f_true = 0
        self.join_pairs = 0                        # J for the next join
        self.levels: List[_Level] = []             # k >= 2 only

    # ------------------------------------------------------------------
    def _bucket(self, m: int) -> int:
        return max(self.m_bucket, -(-m // self.m_bucket) * self.m_bucket)

    def seed_items(self, item_ids: np.ndarray) -> None:
        """Install the level-1 frequent items (host-known from the k=1
        count readback) as the first device frequent matrix."""
        self.k = 1
        self.f_true = int(len(item_ids))
        f_cap = self._bucket(self.f_true)
        ids = np.zeros((f_cap, 1), np.int32)
        ids[:self.f_true, 0] = np.sort(np.asarray(item_ids))
        self.F = self.meter.h2d(ids)
        self.valid = jnp.arange(f_cap) < self.f_true
        self.join_pairs = self.f_true * (self.f_true - 1) // 2

    def _device_join_ok(self) -> bool:
        kc = self.k + 1
        return (int(self.F.shape[0]) <= self.max_join_rows
                and self.join_pairs <= self.max_candidates
                and self.n_items ** (kc - 1) < int(_INVALID_KEY))

    # ------------------------------------------------------------------
    def join(self):
        """Produce level k+1 candidates.  Device path moves at most one
        scalar (the post-prune survivor count, k >= 3) so the counting
        round is sized to the survivors, not the raw join width; the
        (guarded) host fallback decodes once and re-uploads, metered."""
        if self.join_pairs <= 0 or self.f_true == 0:
            return None
        if self._device_join_ok():
            m_cap = self._bucket(self.join_pairs)
            C, valid_c, bitmap = _join_prune(
                self.F, self.valid, m_cap=m_cap, n_items=self.n_items)
            if self.k >= 2:
                # the prune can drop most join pairs; counting over the
                # pre-prune J-sized block would redo their matmul columns
                # every tile.  One scalar readback (in the serial candgen
                # phase — the map round keeps its single sync) shrinks the
                # round to the post-prune bucket: survivors are compacted
                # at the front, so slicing is exact.
                n_surv = int(self.meter.d2h(valid_c.sum()))
                if n_surv == 0:        # everything pruned: dry round, as
                    self.join_pairs = 0  # when the host join returns []
                    return None
                m_post = self._bucket(n_surv)
                if m_post < m_cap:
                    C, valid_c, bitmap = (C[:m_post], valid_c[:m_post],
                                          bitmap[:m_post])
                    m_cap = m_post
            return C, valid_c, bitmap, m_cap
        # fallback: frequent set too wide (quadratic join mask) or keys
        # would overflow — run the reference host join on decoded tuples
        rows = self.meter.d2h(self.F[:self.f_true])
        cands = generate_candidates(
            [tuple(int(v) for v in r) for r in rows])
        if not cands:
            self.join_pairs = 0
            return None
        m_cap = self._bucket(len(cands))
        Ch = np.zeros((m_cap, self.k + 1), np.int32)
        Ch[:len(cands)] = np.asarray(cands, np.int32)
        bitmap = self.meter.h2d(pad_candidates(
            itemsets_to_bitmap(cands, self.n_items), m_cap))
        return (self.meter.h2d(Ch), jnp.arange(m_cap) < len(cands),
                bitmap, m_cap)

    # ------------------------------------------------------------------
    def finalize(self, acc: jnp.ndarray, C: jnp.ndarray,
                 valid_c: jnp.ndarray, min_sup: int):
        """Device-side round close — see :func:`_finalize`."""
        return _finalize(acc, C, valid_c, min_sup)

    def advance(self, packed: np.ndarray, Fn: jnp.ndarray,
                vn: jnp.ndarray, min_sup: int) -> Tuple[int, int]:
        """Consume a round's packed readback; returns (n_candidates,
        n_frequent) for the round report."""
        counts, J = packed[:-1], int(packed[-1])
        m_true = int((counts >= 0).sum())
        freq_counts = counts[counts >= min_sup].astype(np.int64)
        f_true = int(freq_counts.size)
        self.k += 1
        self.f_true = f_true
        if f_true:
            f_cap = self._bucket(f_true)       # shrink to the small bucket
            self.F, self.valid = Fn[:f_cap], vn[:f_cap]
            self.join_pairs = J
            self.levels.append(_Level(self.F, f_true, freq_counts))
        else:
            self.join_pairs = 0
        return m_true, f_true

    # ------------------------------------------------------------------
    @property
    def n_frequent_total(self) -> int:
        """Frequent itemsets mined at levels >= 2 (sizes the rules phase
        without decoding anything)."""
        return sum(lv.f_true for lv in self.levels)

    def decode_supports(self) -> Dict[Tuple[int, ...], int]:
        """The one place itemset tuples reach the host: one d2h per mined
        level, at rule-generation time."""
        out: Dict[Tuple[int, ...], int] = {}
        for lv in self.levels:
            rows = self.meter.d2h(lv.F[:lv.f_true])
            for r, c in zip(rows, lv.counts):
                out[tuple(int(v) for v in r)] = int(c)
        return out
