"""Structured accounting for one end-to-end pipeline run.

Every pipeline phase produces a record here; nothing is printed as a side
effect.  Since the unified-runtime refactor, every phase is a
:class:`repro.runtime.PhaseRecord` emitted by ``Runtime.run_phase`` /
``run_serial``, and the report's totals are derived from the attached
:class:`repro.runtime.ExecLedger` slice — the same ledger semantics the
serving and sharded planes use, so the planes cannot drift on what a
second or a joule means.  ``RoundReport`` remains the per-Apriori-level
view (candidate counts, tile histograms, kernel batch shapes) assembled
from those records.

Time/energy semantics: ``serial`` phases run on one core chosen by
``MBScheduler.assign_serial`` with every other core power-gated; ``map``
phases are tiled across the heterogeneity profile, and their energy charges
active watts for busy seconds, idle watts for the tail each core waits on
the makespan, gated watts for cores that ran nothing, plus the per-move
joule cost of dynamic core switching (switches and speculative re-issues
both migrate work, so both are priced).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.runtime.ledger import ExecLedger, PhaseRecord

# A single-threaded phase routed to one core (paper §V function 3) is just
# a serial PhaseRecord; the old name stays exported for callers/tests.
SerialPhase = PhaseRecord


@dataclass
class RoundReport:
    """One Apriori level: serial candidate generation + tiled support count."""

    k: int
    n_candidates: int
    n_frequent: int
    n_tiles: int
    tiles_per_device: List[int]   # Σ == n_tiles (invariant, tested)
    map_makespan_s: float
    map_busy_s: List[float]
    switches: int
    reissued: int
    energy_j: float
    serial: Optional[PhaseRecord] = None    # None for k=1 (no candidate gen)
    m_padded: int = 0             # data-plane candidate batch (0 = host path)
    failed_devices: List[int] = field(default_factory=list)

    @property
    def time_s(self) -> float:
        return self.map_makespan_s + (self.serial.sim_time_s if self.serial else 0.0)

    @classmethod
    def from_phases(cls, k: int, n_candidates: int, n_frequent: int,
                    map_phase: Optional[PhaseRecord],
                    serial: Optional[PhaseRecord] = None,
                    m_padded: int = 0, n_devices: int = 0) -> "RoundReport":
        """Assemble the per-round view from the runtime's phase records."""
        if map_phase is None:                # candidate generation came up dry
            return cls(k=k, n_candidates=n_candidates, n_frequent=n_frequent,
                       n_tiles=0, tiles_per_device=[0] * n_devices,
                       map_makespan_s=0.0, map_busy_s=[0.0] * n_devices,
                       switches=0, reissued=0, energy_j=0.0, serial=serial,
                       m_padded=m_padded)
        return cls(k=k, n_candidates=n_candidates, n_frequent=n_frequent,
                   n_tiles=map_phase.n_tiles,
                   tiles_per_device=list(map_phase.tiles_done),
                   map_makespan_s=map_phase.sim_time_s,
                   map_busy_s=list(map_phase.busy_s),
                   switches=map_phase.switches, reissued=map_phase.reissued,
                   energy_j=map_phase.energy_j, serial=serial,
                   m_padded=m_padded,
                   failed_devices=list(map_phase.failed_devices))


@dataclass
class PipelineReport:
    """The full run: config echo, per-round records, and ledger totals."""

    backend: str                  # "pallas" | "ref"
    policy: str                   # switching policy: static|dynamic|costmodel
    profile_speeds: List[float]
    n_tx: int
    n_items: int
    n_tiles: int
    min_support: int              # absolute, after fraction resolution
    algorithm: str = "apriori"    # mining backend: "apriori" | "eclat"
    split: str = "lpt"            # tile split: lpt | proportional | equal
    rounds: List[RoundReport] = field(default_factory=list)
    rules_phase: Optional[PhaseRecord] = None
    n_itemsets: int = 0
    n_rules: int = 0
    wall_time_s: float = 0.0      # host wall clock for the whole run
    ledger: Optional[ExecLedger] = None   # this run's phase records
    # distributed mining plane (execution == "sharded"):
    execution: str = "simulated"  # "simulated" | "sharded" | "out_of_core"
    n_shards: int = 0             # mesh axis size (0 = single-device plane)
    shard_rows: List[int] = field(default_factory=list)  # final plan, per rank
    replans: int = 0              # failure-triggered shard re-plans
    # out-of-core SON plane (execution == "out_of_core"):
    n_partitions: int = 0         # disk-resident chunks the corpus split into
    partition_rows: int = 0       # configured rows per chunk
    partitions_resumed: int = 0   # partition passes skipped via checkpoint
    checkpoint_saves: int = 0     # son_state boundary checkpoints written
    checkpoint_bytes: int = 0     # total bytes across those saves

    # ------------------------------------------------------------------
    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def map_time_s(self) -> float:
        """Sum of map-phase makespans only — the policy-sensitive part (the
        serial phases are schedule-invariant), comparable to the paper's
        analytic speedup bound."""
        return sum(r.map_makespan_s for r in self.rounds)

    @property
    def total_time_s(self) -> float:
        if self.ledger is not None:
            return self.ledger.total_time_s
        t = sum(r.time_s for r in self.rounds)
        if self.rules_phase:
            t += self.rules_phase.sim_time_s
        return t

    @property
    def total_energy_j(self) -> float:
        if self.ledger is not None:
            return self.ledger.total_energy_j
        e = sum(r.energy_j + (r.serial.energy_j if r.serial else 0.0)
                for r in self.rounds)
        if self.rules_phase:
            e += self.rules_phase.energy_j
        return e

    @property
    def total_switches(self) -> int:
        if self.ledger is not None:
            return self.ledger.total_switches
        return sum(r.switches for r in self.rounds)

    @property
    def total_reissued(self) -> int:
        if self.ledger is not None:
            return self.ledger.total_reissued
        return sum(r.reissued for r in self.rounds)

    @property
    def constraint_violations(self) -> int:
        """Serial phases whose min_speed no core could satisfy (flagged by
        assign_serial instead of silently falling back)."""
        if self.ledger is None:
            return 0
        return len(self.ledger.constraint_violations())

    @property
    def kernel_batches(self) -> List[int]:
        """Distinct data-plane candidate batch shapes (jit cache entries)."""
        return sorted({r.m_padded for r in self.rounds if r.m_padded})

    # ------------------------------------------------------------------
    def summary(self) -> str:
        lines = [
            f"MarketBasketPipeline: algorithm={self.algorithm} "
            f"backend={self.backend} "
            f"policy={self.policy} split={self.split} "
            f"cores={self.profile_speeds}",
        ]
        if self.execution == "sharded":
            lines.append(
                f"  sharded: {self.n_shards} mesh ranks, rows/rank "
                f"{'/'.join(map(str, self.shard_rows))}, "
                f"{self.replans} re-plans")
        if self.execution == "out_of_core":
            lines.append(
                f"  out-of-core: {self.n_partitions} partitions x "
                f"{self.partition_rows} rows, "
                f"{self.partitions_resumed} resumed from checkpoint, "
                f"{self.checkpoint_saves} checkpoints "
                f"({self.checkpoint_bytes} B), {self.replans} re-plans")
        lines += [
            f"  data: {self.n_tx} tx x {self.n_items} items, "
            f"{self.n_tiles} tiles, min_support={self.min_support}",
            f"  {'round':>7s} {'cands':>6s} {'freq':>6s} {'serial_s':>9s} "
            f"{'map_s':>9s} {'energy_J':>9s} {'sw':>3s} {'re':>3s} "
            f"{'tiles/core':>14s} {'Mpad':>5s}",
        ]
        for r in self.rounds:
            ser = r.serial.sim_time_s if r.serial else 0.0
            e = r.energy_j + (r.serial.energy_j if r.serial else 0.0)
            lines.append(
                f"  {('k=' + str(r.k)):>7s} {r.n_candidates:6d} {r.n_frequent:6d} "
                f"{ser:9.4f} {r.map_makespan_s:9.4f} {e:9.1f} "
                f"{r.switches:3d} {r.reissued:3d} "
                f"{'/'.join(map(str, r.tiles_per_device)):>14s} {r.m_padded:5d}")
        if self.rules_phase:
            lines.append(f"  rules: {self.n_rules} rules on core "
                         f"{self.rules_phase.device} "
                         f"({self.rules_phase.sim_time_s:.4f}s, "
                         f"{self.rules_phase.energy_j:.1f}J, others gated)")
        lines.append(
            f"  totals: {self.n_rounds} rounds, {self.n_itemsets} frequent "
            f"itemsets, {self.n_rules} rules | simulated "
            f"{self.total_time_s:.4f}s, {self.total_energy_j:.1f}J, "
            f"{self.total_switches} core switches, "
            f"{self.total_reissued} speculative re-issues | "
            f"wall {self.wall_time_s:.2f}s, kernel batches {self.kernel_batches}")
        if self.constraint_violations:
            lines.append(f"  WARNING: {self.constraint_violations} serial "
                         f"phase(s) ran on a core below their min_speed")
        return "\n".join(lines)

    def tiles_invariant_ok(self) -> bool:
        """Every map round's per-device tile counts must sum to the job size."""
        return all(sum(r.tiles_per_device) == r.n_tiles for r in self.rounds)
