"""Streaming plane: incremental Apriori over a sliding transaction window,
feeding live rule-index refreshes into the serving plane (the closed loop
the paper's continuously-operating system implies)."""
from repro.streaming.miner import (BatchReport, StreamingConfig,
                                   StreamingMiner, StreamingReport)
from repro.streaming.source import SlidingWindow, TransactionStream

__all__ = [
    "BatchReport", "SlidingWindow", "StreamingConfig", "StreamingMiner",
    "StreamingReport", "TransactionStream",
]
