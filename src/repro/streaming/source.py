"""Transaction sources for the streaming plane.

:class:`TransactionStream` turns any transaction corpus (a packed 0/1
bitmap or variable-length item-id lists) into a sequence of fixed-size
micro-batches — the arrival process the :class:`StreamingMiner` consumes.

:class:`SlidingWindow` is the miner's state: the last ``capacity``
transactions, in arrival order.  ``push()`` returns the *slabs* whose
supports changed — the rows that arrived and the rows that fell out of
the window — which is exactly what delta support counting needs: support
over the window is linear in rows, so

  supp_new(c) = supp_old(c) + supp_arrived(c) - supp_evicted(c)

holds for every candidate ``c``, no matter how the window moved (this is
why a batch larger than the window is still exact: rows that arrive and
evict in the same push appear in both slabs and cancel).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.baskets import pack_transactions, pad_items

Corpus = np.ndarray


class TransactionStream:
    """Micro-batch view over a transaction corpus.

    ``T`` is either a packed 0/1 bitmap ``uint8[n_tx, n_items]`` or a
    sequence of item-id transactions (packed on entry).  Iteration yields
    ``uint8[b, n_items]`` slabs of ``batch_size`` rows (the final slab may
    be short).  The stream is replayable: each ``__iter__`` starts over.
    """

    def __init__(self, T, batch_size: int,
                 n_items: Optional[int] = None):
        if batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        if not isinstance(T, np.ndarray):
            T = pack_transactions(T, n_items)
        if T.ndim != 2:
            raise ValueError(f"corpus must be 2-D, got shape {T.shape}")
        if T.size and not ((T == 0) | (T == 1)).all():
            raise ValueError("corpus bitmap must contain only 0/1")
        self.T = T.astype(np.uint8, copy=False)
        self.batch_size = int(batch_size)

    @property
    def n_tx(self) -> int:
        return int(self.T.shape[0])

    @property
    def n_items(self) -> int:
        return int(self.T.shape[1])

    @property
    def n_batches(self) -> int:
        return -(-self.n_tx // self.batch_size) if self.n_tx else 0

    def __iter__(self) -> Iterator[np.ndarray]:
        for i in range(0, self.n_tx, self.batch_size):
            yield self.T[i:i + self.batch_size]

    def take(self, k: int) -> List[np.ndarray]:
        """The first ``k`` micro-batches (fewer if the corpus runs out)."""
        out: List[np.ndarray] = []
        for batch in self:
            if len(out) >= k:
                break
            out.append(batch)
        return out


class SlidingWindow:
    """The last ``capacity`` transactions, with arrive/evict slab deltas.

    Rows are stored lane-padded (item axis padded to 128, the kernel
    layout) so slabs and the materialized window go straight to the
    support-count data plane.  ``n_items`` is the raw item-universe width;
    every pushed batch must match it.
    """

    def __init__(self, capacity: int, n_items: int):
        if capacity <= 0:
            raise ValueError(f"window capacity must be positive: {capacity}")
        if n_items <= 0:
            raise ValueError(f"n_items must be positive: {n_items}")
        self.capacity = int(capacity)
        self.n_items = int(n_items)
        self.n_items_padded = n_items + (-n_items) % 128
        self._rows: Deque[np.ndarray] = deque()

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def n(self) -> int:
        return len(self._rows)

    @property
    def full(self) -> bool:
        return len(self._rows) >= self.capacity

    # ------------------------------------------------------------------
    def push(self, batch: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Admit a micro-batch; returns ``(arrived, evicted)`` slabs.

        Both slabs are lane-padded ``uint8[b, n_items_padded]``; the
        evicted slab has zero rows until the window fills.  Rows of a
        batch larger than the window appear in both slabs (arrived then
        immediately evicted) so the delta algebra stays exact.
        """
        batch = np.asarray(batch, dtype=np.uint8)
        if batch.ndim != 2 or batch.shape[1] != self.n_items:
            raise ValueError(f"batch must be [b, {self.n_items}], got "
                             f"{batch.shape}")
        # own the rows: pad_items is a no-op when n_items is already a
        # multiple of 128, and deque rows that alias a caller buffer would
        # silently mutate the window if the caller reuses it
        arrived = pad_items(batch).copy()
        evicted_rows: List[np.ndarray] = []
        for row in arrived:
            self._rows.append(row)
            if len(self._rows) > self.capacity:
                evicted_rows.append(self._rows.popleft())
        evicted = (np.stack(evicted_rows) if evicted_rows
                   else np.zeros((0, self.n_items_padded), dtype=np.uint8))
        return arrived, evicted

    # ------------------------------------------------------------------
    def rows(self) -> np.ndarray:
        """The window contents in arrival order, lane-padded.

        This is byte-for-byte what a one-shot pipeline over "the same
        window" ingests (``ingest_baskets`` pads the same way), which is
        what the parity smoke compares against.
        """
        if not self._rows:
            return np.zeros((0, self.n_items_padded), dtype=np.uint8)
        return np.stack(list(self._rows))

    def rows_raw(self) -> np.ndarray:
        """Window contents over the raw item universe (padding sliced)."""
        return self.rows()[:, :self.n_items]
