"""StreamingMiner — incremental Apriori over a sliding transaction window.

The paper's system is continuously operating: transactions keep arriving,
the mining job refreshes, and the recommendation layer consumes fresh
rules.  Re-mining the window from scratch on every micro-batch repeats
work proportional to the *window*; this plane does work proportional to
the *batch*:

  micro-batch ──▶ SlidingWindow.push ──▶ (arrived, evicted) slabs
     │
     ├─ delta phase (map): support_count on just the slabs —
     │    supp += count(arrived) - count(evicted)   for every tracked
     │    itemset, plus the item-frequency vector (support over the
     │    window is linear in rows, so the update is exact)
     ├─ check phase (serial): recompute the frequent/infrequent status
     │    of every tracked itemset under the new window's min_support
     ├─ re-validation (only when the lattice can change): if any tracked
     │    itemset crossed the frequency boundary, candidate sets are no
     │    longer trustworthy — run a full Apriori pass over the window
     │    and rebuild the tracked set
     ├─ rules phase (serial, only when supports moved): regenerate rules
     │    and hot-swap them into the live RecommendationEngine via the
     │    RuleIndex.refresh() atomic swap
     ▼
  StreamingReport (per-batch records + the shared-runtime ledger slice)

Exactness argument (why the final state is bit-identical to a one-shot
``MarketBasketPipeline`` over the same window): the *tracked set* is the
full candidate set of the last validation — every frequent itemset plus
the negative border (candidates that failed min_support).  Item (k=1)
counts are maintained exactly for every item.  If the window's frequent
set changes at all, downward closure implies some minimal changed itemset
has all proper subsets frequent before and after — so it was a candidate,
hence tracked, and its boundary crossing is detected, which triggers the
full re-validation.  Between re-validations the lattice is provably
unchanged and the delta-maintained counters are exact, so supports (and
the rules derived from them) match the from-scratch mine bit for bit.

All phases are routed through the shared :class:`repro.runtime.Runtime`
(``run_serial`` / ``run_phase``), so the ledger prices streaming time,
energy and core switches exactly like the other planes, and the
``policy=`` knob (static | dynamic | costmodel) is honored: the delta and
validation map phases are planned by the switching policy over the
heterogeneity profile.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import (AprioriResult, generate_candidates,
                                 itemsets_to_bitmap)
from repro.core.power import PowerModel
from repro.core.rules import Rule, generate_rules
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.pipeline.dataplane import DataPlane, uniform_tiles
from repro.pipeline.pipeline import (PipelineConfig, candgen_cost,
                                     support_flops)
from repro.runtime import (ExecLedger, MeasuredPhase, Runtime, SlabPool,
                           SwitchingPolicy, autotuned_costmodel, donated_add)
from repro.serving.engine import RecommendationEngine
from repro.serving.index import RuleIndex
from repro.streaming.source import SlidingWindow

Itemset = Tuple[int, ...]


@dataclass(frozen=True)
class StreamingConfig:
    """Knobs for the streaming plane (superset of the mining thresholds).

    ``window`` / ``batch_size`` shape the arrival process; the mining
    thresholds (``min_support`` as a fraction of the *current window
    fill*, ``min_confidence``, ``min_lift``, ``max_k``) carry the exact
    :class:`repro.pipeline.PipelineConfig` semantics so incremental and
    one-shot mining agree bit for bit.  ``refresh_every`` rate-limits the
    rules/index refresh (1 = refresh whenever supports moved; a
    re-validation always refreshes); ``revalidate_every`` forces a
    periodic full Apriori pass on top of the boundary-crossing trigger
    (0 = trigger-only, which is already exact).
    """

    window: int = 2048
    batch_size: int = 128
    min_support: float = 0.02
    min_confidence: float = 0.6
    min_lift: float = 0.0
    max_k: int = 0                  # 0 = mine until no candidates survive
    n_tiles: int = 8                # validation-pass map tiles
    round_execution: str = "pipelined"  # pipelined | per_tile (see PipelineConfig)
    policy: str = "static"          # switching: static | dynamic | costmodel
    split: str = "lpt"              # tile split: equal | proportional | lpt
    data_plane: str = "auto"        # auto | pallas | ref
    m_bucket: int = 128             # candidate-batch rounding (kernel lanes)
    interpret: Optional[bool] = None
    autotune: bool = True           # kernel winner cache on (see PipelineConfig)
    power: str = "cpu"              # cpu | tpu_v5e | none
    refresh_every: int = 1          # batches between rule/index refreshes
    revalidate_every: int = 0       # 0 = only when the lattice can change
    serial_unit_cost: float = 64.0  # same work units as PipelineConfig
    serial_min_speed: float = 0.0   # min core speed for serial phases

    def abs_support(self, n_tx: int) -> int:
        return PipelineConfig(min_support=self.min_support).abs_support(n_tx)

    def pipeline_config(self, **overrides) -> PipelineConfig:
        """The equivalent one-shot config (parity smokes mine with this)."""
        kw = dict(min_support=self.min_support,
                  min_confidence=self.min_confidence,
                  min_lift=self.min_lift, max_k=self.max_k,
                  n_tiles=self.n_tiles,
                  round_execution=self.round_execution,
                  policy=self.policy, split=self.split,
                  data_plane=self.data_plane, m_bucket=self.m_bucket,
                  interpret=self.interpret, autotune=self.autotune,
                  power=self.power,
                  serial_unit_cost=self.serial_unit_cost,
                  serial_min_speed=self.serial_min_speed)
        kw.update(overrides)
        return PipelineConfig(**kw)


@dataclass
class BatchReport:
    """Accounting for one micro-batch through the streaming plane."""

    idx: int
    n_arrived: int
    n_evicted: int
    window_n: int
    min_support: int               # absolute, under the new window fill
    revalidated: bool = False
    rules_refreshed: bool = False
    index_swapped: bool = False
    n_frequent: int = 0
    n_rules: int = 0
    index_version: int = 0
    n_phases: int = 0              # PhaseRecords this batch emitted
    time_s: float = 0.0            # simulated seconds (ledger slice)
    refresh_latency_s: float = 0.0  # host wall: rules regen -> index visible
    wall_s: float = 0.0


@dataclass
class StreamingReport:
    """The streaming twin of PipelineReport: per-batch records + ledger."""

    backend: str
    policy: str
    split: str
    window: int
    batch_size: int
    n_items: int = 0
    batches: List[BatchReport] = field(default_factory=list)
    wall_time_s: float = 0.0
    ledger: Optional[ExecLedger] = None

    @property
    def n_batches(self) -> int:
        return len(self.batches)

    @property
    def n_revalidations(self) -> int:
        return sum(1 for b in self.batches if b.revalidated)

    @property
    def n_refreshes(self) -> int:
        return sum(1 for b in self.batches if b.rules_refreshed)

    @property
    def total_time_s(self) -> float:
        return self.ledger.total_time_s if self.ledger else 0.0

    @property
    def total_energy_j(self) -> float:
        return self.ledger.total_energy_j if self.ledger else 0.0

    @property
    def total_switches(self) -> int:
        return self.ledger.total_switches if self.ledger else 0

    @property
    def total_reissued(self) -> int:
        return self.ledger.total_reissued if self.ledger else 0

    @property
    def constraint_violations(self) -> int:
        if self.ledger is None:
            return 0
        return len(self.ledger.constraint_violations())

    @property
    def mean_refresh_latency_s(self) -> float:
        lats = [b.refresh_latency_s for b in self.batches
                if b.rules_refreshed]
        return float(np.mean(lats)) if lats else 0.0

    def summary(self) -> str:
        last = self.batches[-1] if self.batches else None
        lines = [
            f"StreamingMiner: backend={self.backend} policy={self.policy} "
            f"split={self.split} window={self.window} "
            f"batch={self.batch_size}",
            f"  {self.n_batches} micro-batches | "
            f"{self.n_revalidations} re-validations, "
            f"{self.n_refreshes} rule refreshes "
            f"(mean refresh-to-visible {self.mean_refresh_latency_s * 1e3:.2f}ms)",
            f"  totals: simulated {self.total_time_s:.4f}s, "
            f"{self.total_energy_j:.1f}J, {self.total_switches} core "
            f"switches, {self.total_reissued} re-issues | "
            f"wall {self.wall_time_s:.3f}s",
        ]
        if last is not None:
            lines.append(
                f"  live state: {last.window_n} tx in window, "
                f"{last.n_frequent} frequent itemsets, {last.n_rules} rules, "
                f"index v{last.index_version}")
        if self.constraint_violations:
            lines.append(f"  WARNING: {self.constraint_violations} serial "
                         f"phase(s) ran on a core below their min_speed")
        return "\n".join(lines)


class StreamingMiner:
    """Incremental miner over a sliding window, feeding a live rule index.

    ``n_items`` fixes the item universe up front (streams cannot grow it:
    the kernel layouts and the serving index are shape-stable).  Attach a
    live :class:`RecommendationEngine` with ``engine=`` or
    :meth:`attach_engine`; every rule refresh then hot-swaps the compiled
    index into it via ``engine.refresh()``.
    """

    def __init__(self, n_items: int,
                 profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[StreamingConfig] = None,
                 engine: Optional[RecommendationEngine] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None,
                 policy: Union[str, SwitchingPolicy, None] = None):
        self.profile = profile or HeterogeneityProfile.paper()
        self.config = config or StreamingConfig()
        cfg = self.config
        if cfg.round_execution not in ("pipelined", "per_tile"):
            raise ValueError(
                f"round_execution must be 'pipelined' or 'per_tile', "
                f"got {cfg.round_execution!r}")
        policy = policy if policy is not None else cfg.policy
        if policy == "costmodel" and cfg.autotune:
            # measured kernel walls replace the datasheet constants
            policy = autotuned_costmodel("support_count")
        self.runtime = Runtime(
            self.profile,
            policy=policy,
            split=cfg.split,
            power=power if power is not None else cfg.power,
            scheduler=scheduler)
        self.scheduler = self.runtime.scheduler
        self.data_plane = DataPlane(cfg.data_plane, m_bucket=cfg.m_bucket,
                                    interpret=cfg.interpret,
                                    tuning=None if cfg.autotune else False,
                                    meter=self.runtime.meter)
        self.slabs = SlabPool()
        self.window = SlidingWindow(cfg.window, n_items)
        self.engine = engine

        # incremental state -------------------------------------------------
        Ip = self.window.n_items_padded
        self._item_counts = np.zeros(Ip, dtype=np.int64)
        self._tracked: List[Itemset] = []     # last validation's candidates
        self._tracked_supp = np.zeros(0, dtype=np.int64)  # aligned counts
        self._levels = 1                      # deepest level the lattice has
        self._freq_items: Optional[frozenset] = None   # None = no lattice yet
        self._freq_tracked: frozenset = frozenset()
        # rules/index state
        self.rules: List[Rule] = []
        self.index: Optional[RuleIndex] = None
        self._rules_state: Optional[Tuple[Dict[Itemset, int], int]] = None
        self._batch_idx = 0
        self._batches: List[BatchReport] = []
        self._wall_s = 0.0

    # ------------------------------------------------------------------
    @property
    def n_items(self) -> int:
        return self.window.n_items

    def attach_engine(self, engine: RecommendationEngine) -> None:
        """Attach (or replace) the live serving engine; the next refresh
        swaps the current index in immediately if one exists."""
        self.engine = engine
        if self.index is not None:
            self.index = engine.refresh(self.index)

    # ------------------------------------------------------------------
    # current mined state (exact between re-validations, see module doc)
    # ------------------------------------------------------------------
    def min_support_abs(self) -> int:
        return self.config.abs_support(max(self.window.n, 1))

    @property
    def supports(self) -> Dict[Itemset, int]:
        """Frequent itemsets -> exact window support (the pipeline dict)."""
        min_sup = self.min_support_abs()
        sup: Dict[Itemset, int] = {
            (int(i),): int(self._item_counts[i])
            for i in np.nonzero(self._item_counts >= min_sup)[0]}
        for c, s in zip(self._tracked, self._tracked_supp):
            if s >= min_sup:
                sup[c] = int(s)
        return sup

    # ------------------------------------------------------------------
    # phase helpers (everything prices through the shared runtime)
    # ------------------------------------------------------------------
    def _run_serial(self, name: str, cost: float, fn=None):
        return self.runtime.run_serial(
            name, cost=cost, fn=fn,
            min_speed=self.config.serial_min_speed)

    def _delta_phase(self, arrived: np.ndarray, evicted: np.ndarray):
        """One map phase over the arrive/evict slabs: item-count vector
        delta plus tracked-candidate support deltas, computed with the
        same support_count data plane the batch pipeline uses."""
        Ip = self.window.n_items_padded
        m_padded = self.data_plane.m_padded if self._tracked else 0
        slabs = [s for s in (arrived, evicted) if s.shape[0]]
        rows = np.array([s.shape[0] for s in slabs], dtype=np.float64)
        tile_costs = rows * Ip * (1.0 + m_padded)
        task = TaskSpec(f"stream-delta-{self._batch_idx}",
                        float(tile_costs.sum()), parallel=True,
                        n_tiles=len(slabs), family="stream-delta")

        meter = self.runtime.meter
        pipelined = self.config.round_execution == "pipelined"

        def execute(_asg, _costs):
            if not pipelined:           # legacy: host math + per-slab syncs
                d_items = (arrived.sum(axis=0, dtype=np.int64)
                           - evicted.sum(axis=0, dtype=np.int64))
                d_supp = np.zeros(len(self._tracked), dtype=np.int64)
                if self._tracked:
                    if arrived.shape[0]:
                        d_supp += self.data_plane.tile_counts(arrived)
                    if evicted.shape[0]:
                        d_supp -= self.data_plane.tile_counts(evicted)
                return MeasuredPhase(result=(d_items, d_supp))
            # pipelined: both slabs' item deltas and tracked-support deltas
            # compute on device; one packed [Ip + m] readback is the batch's
            # single sync point
            m = len(self._tracked)
            d_items = jnp.zeros(Ip, jnp.int32)
            d_supp = jnp.zeros(m, jnp.int32)
            for sign, slab in ((1, arrived), (-1, evicted)):
                if not slab.shape[0]:
                    continue
                dev = meter.h2d(slab)
                d_items = d_items + sign * dev.sum(axis=0, dtype=jnp.int32)
                if m:
                    d_supp = (d_supp + sign
                              * self.data_plane.tile_counts_device(dev)[:m])
            packed = meter.d2h(jnp.concatenate([d_items, d_supp]),
                               dtype=np.int64)
            return MeasuredPhase(result=(packed[:Ip], packed[Ip:]))

        (d_items, d_supp), rec = self.runtime.run_phase(
            task, execute, tile_costs=tile_costs,
            tile_flops=support_flops(rows, Ip, m_padded))
        self._item_counts += d_items
        if len(d_supp):
            self._tracked_supp += d_supp
        return rec

    # ------------------------------------------------------------------
    def _validate(self) -> None:
        """Full Apriori pass over the window: rebuild the tracked set
        (all candidates, frequent AND the negative border) and its exact
        supports.  Runs only when the lattice can have changed."""
        cfg = self.config
        min_sup = self.min_support_abs()
        Ip = self.window.n_items_padded
        W = self.window.rows()
        meter = self.runtime.meter
        pipelined = cfg.round_execution == "pipelined"
        tiles = [meter.h2d(t) for t in uniform_tiles(W, cfg.n_tiles)]
        tile_rows = np.array([t.shape[0] for t in tiles], dtype=np.float64)

        frequent: List[Itemset] = [
            (int(i),) for i in np.nonzero(self._item_counts >= min_sup)[0]]
        tracked: List[Itemset] = []
        tracked_supp: List[int] = []
        k = 2
        # NOTE: this loop mirrors MarketBasketPipeline.run's rounds k>=2
        # (shared candgen_cost/support_flops pricing, same DataPlane and
        # generate_candidates) but additionally RETAINS the infrequent
        # candidates — the negative border the delta path tracks.  A
        # semantic change to the pipeline's round loop must land here too;
        # the parity smoke and test_streaming_props enforce that.
        while frequent and (cfg.max_k == 0 or k <= cfg.max_k):
            cands, _ = self._run_serial(
                f"stream-validate-candgen-k{k}",
                cost=candgen_cost(len(frequent), k, cfg.serial_unit_cost),
                fn=lambda fr=frequent: generate_candidates(fr))
            if not cands:
                break
            self.data_plane.prepare(itemsets_to_bitmap(cands, Ip))
            m_padded = self.data_plane.m_padded
            task = TaskSpec(f"stream-validate-k{k}",
                            float(tile_rows.sum() * Ip * m_padded),
                            parallel=True, n_tiles=len(tiles),
                            family="stream-validate")

            def execute(_asg, _costs, tiles=tiles, m=len(cands),
                        m_pad=m_padded):
                if pipelined:   # donated device accumulate, one sync/level
                    acc = self.slabs.take((m_pad,), jnp.int32)
                    for t in tiles:
                        acc = donated_add(
                            acc, self.data_plane.tile_counts_device(t))
                    counts = meter.d2h(acc[:m], dtype=np.int64)
                    self.slabs.give(acc)
                    return MeasuredPhase(result=counts)
                counts = np.zeros(m, dtype=np.int64)
                for t in tiles:
                    counts += self.data_plane.tile_counts(t)
                return MeasuredPhase(result=counts)

            counts, _ = self.runtime.run_phase(
                task, execute, tile_costs=tile_rows * Ip * m_padded,
                tile_flops=support_flops(tile_rows, Ip, m_padded))
            tracked.extend(cands)
            tracked_supp.extend(int(s) for s in counts)
            frequent = [c for c, s in zip(cands, counts) if s >= min_sup]
            k += 1

        self._tracked = tracked
        self._tracked_supp = np.array(tracked_supp, dtype=np.int64)
        self._levels = k - 1
        if tracked:
            self.data_plane.prepare(itemsets_to_bitmap(tracked, Ip))
        self._snapshot_lattice(min_sup)

    def _snapshot_lattice(self, min_sup: int) -> None:
        self._freq_items = frozenset(
            int(i) for i in np.nonzero(self._item_counts >= min_sup)[0])
        self._freq_tracked = frozenset(
            c for c, s in zip(self._tracked, self._tracked_supp)
            if s >= min_sup)

    def _lattice_stale(self, min_sup: int) -> bool:
        """True when a tracked itemset (or an item) crossed the frequency
        boundary — the only way the window's frequent set can differ from
        the last validation's (downward closure; see module docstring)."""
        if self._freq_items is None:
            return True
        freq_items = frozenset(
            int(i) for i in np.nonzero(self._item_counts >= min_sup)[0])
        if freq_items != self._freq_items:
            return True
        freq_tracked = frozenset(
            c for c, s in zip(self._tracked, self._tracked_supp)
            if s >= min_sup)
        return freq_tracked != self._freq_tracked

    # ------------------------------------------------------------------
    def _refresh_rules(self, report: BatchReport,
                       sup: Optional[Dict[Itemset, int]] = None) -> None:
        """Regenerate rules from the current supports and hot-swap the
        compiled index into the live engine (atomic ``refresh()``)."""
        cfg = self.config
        t0 = time.perf_counter()
        if sup is None:
            sup = self.supports
        state = (sup, self.window.n)
        if state == self._rules_state:      # supports did not move: no-op
            return
        rules, _ = self._run_serial(
            f"stream-rules-{self._batch_idx}",
            cost=max(1.0, len(sup) * cfg.serial_unit_cost),
            fn=lambda: generate_rules(
                AprioriResult(supports=sup, n_tx=self.window.n,
                              levels=self._levels),
                cfg.min_confidence, min_lift=cfg.min_lift))
        self._rules_state = state
        report.rules_refreshed = True
        if rules != self.rules or self.index is None:
            self.rules = rules
            version = (self.index.version + 1) if self.index else 0
            index, _ = self._run_serial(
                f"stream-refresh-{self._batch_idx}",
                cost=max(1.0, (len(rules) + 1) * cfg.serial_unit_cost),
                fn=lambda: RuleIndex.build(rules, self.n_items,
                                           version=version))
            if self.engine is not None:
                index = self.engine.refresh(index)
            self.index = index
            report.index_swapped = True
        report.refresh_latency_s = time.perf_counter() - t0
        report.n_rules = len(self.rules)
        report.index_version = self.index.version if self.index else 0

    # ------------------------------------------------------------------
    def process_batch(self, batch: np.ndarray) -> BatchReport:
        """Consume one micro-batch end to end; returns its BatchReport."""
        cfg = self.config
        t0 = time.perf_counter()
        ledger_mark = self.runtime.ledger.mark()
        sim_mark = self.runtime.ledger.total_time_s

        arrived, evicted = self.window.push(batch)
        report = BatchReport(idx=self._batch_idx,
                             n_arrived=int(arrived.shape[0]),
                             n_evicted=int(evicted.shape[0]),
                             window_n=self.window.n,
                             min_support=self.min_support_abs())
        self._delta_phase(arrived, evicted)

        min_sup = self.min_support_abs()
        due = (cfg.revalidate_every > 0
               and (self._batch_idx + 1) % cfg.revalidate_every == 0)
        stale, _ = self._run_serial(
            f"stream-check-{self._batch_idx}",
            cost=max(1.0, (len(self._tracked) + 1) * cfg.serial_unit_cost),
            fn=lambda: self._lattice_stale(min_sup))
        if stale or due:
            self._validate()
            report.revalidated = True

        sup = self.supports             # built once per batch (hot path)
        if (self._batch_idx % max(cfg.refresh_every, 1) == 0
                or report.revalidated):
            self._refresh_rules(report, sup)
        report.n_frequent = len(sup)
        report.n_rules = len(self.rules)
        report.index_version = self.index.version if self.index else 0

        report.n_phases = self.runtime.ledger.mark() - ledger_mark
        report.time_s = self.runtime.ledger.total_time_s - sim_mark
        report.wall_s = time.perf_counter() - t0
        self._wall_s += report.wall_s
        self._batches.append(report)
        self._batch_idx += 1
        return report

    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Force a rules/index refresh if supports moved since the last
        one (closes a ``refresh_every`` gap at end of stream)."""
        if not self._batches:
            return
        report = self._batches[-1]
        # flush-time phases are charged to the last batch so the per-batch
        # phase counts still sum to the ledger slice exactly
        ledger_mark = self.runtime.ledger.mark()
        sim_mark = self.runtime.ledger.total_time_s
        t0 = time.perf_counter()
        self._refresh_rules(report)
        report.n_phases += self.runtime.ledger.mark() - ledger_mark
        report.time_s += self.runtime.ledger.total_time_s - sim_mark
        wall = time.perf_counter() - t0
        report.wall_s += wall
        self._wall_s += wall
        report.n_rules = len(self.rules)
        report.index_version = self.index.version if self.index else 0

    def take_report(self) -> StreamingReport:
        """Slice this miner's accumulated accounting into a report (and
        reset it, mirroring the other planes' per-run ledger slices)."""
        report = StreamingReport(
            backend=self.data_plane.backend, policy=self.runtime.policy.name,
            split=self.runtime.split, window=self.config.window,
            batch_size=self.config.batch_size, n_items=self.n_items,
            batches=self._batches, wall_time_s=self._wall_s,
            ledger=self.runtime.ledger.take_since(0))
        self._batches = []
        self._wall_s = 0.0
        return report

    def run(self, stream, max_batches: Optional[int] = None
            ) -> StreamingReport:
        """Consume a stream (any iterable of row slabs), flush, report."""
        for i, batch in enumerate(stream):
            if max_batches is not None and i >= max_batches:
                break
            self.process_batch(batch)
        self.flush()
        return self.take_report()
