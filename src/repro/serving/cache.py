"""LRU result cache for the serving engine.

Keys are canonicalized basket bitmaps (packed bits over the *true* item
universe, so lane padding and input form — id list vs 0/1 row — cannot
split one logical basket across entries).  Values are the final filtered
recommendation lists, so a hit skips the kernel entirely.

Hit/miss counters are cumulative for the cache's lifetime; the engine
reports per-``serve`` deltas.  ``maxsize=0`` disables caching (every
lookup is a miss), which is the "cache off" arm of the B7 benchmark.
The engine clears the cache on index ``refresh()`` — entries computed
against a stale index must never be served.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

Recommendation = List[Tuple[int, float]]


def basket_key(bits: np.ndarray) -> bytes:
    """Canonical cache key for a 0/1 basket vector over the true items."""
    return np.packbits(np.asarray(bits, dtype=np.uint8)).tobytes()


class ResultCache:
    def __init__(self, maxsize: int = 4096):
        if maxsize < 0:
            raise ValueError(f"maxsize must be >= 0, got {maxsize}")
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._entries: "OrderedDict[bytes, Recommendation]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: bytes) -> Optional[Recommendation]:
        if self.maxsize and key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            # copy out: a caller mutating its result must not corrupt the
            # entry every later hit would see
            return list(self._entries[key])
        self.misses += 1
        return None

    def put(self, key: bytes, value: Recommendation) -> None:
        if not self.maxsize:
            return
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        """Drop all entries (index refresh); counters keep accumulating."""
        self._entries.clear()
