"""AsyncServer — continuous-batching open-loop serving (MaxText pattern).

The closed-loop ``RecommendationEngine.serve(list_of_queries)`` sweep
measures batch throughput; it cannot measure what concurrent users
experience, because a live caller holds one request, not the trace.  This
module rebuilds the serving plane around an *open* request loop:

  submit(query) ──▶ RequestQueue (thread-safe, FIFO, arrival-gated)
                        │
        drain loop:     ▼
          slot-based admission — up to ``slots`` arrived requests are
          taken; a partial batch runs on the smallest covering bucket of
          the AOT-warmed :class:`~repro.serving.admission.BucketLadder`
          (coalescing: no request ever waits for a full batch)
                        │
          SLO governor — with ``slo_ms`` set, requests whose projected
          completion (queue delay + EWMA of measured step walls) misses
          the budget are shed at admission, as first-class ``kind="shed"``
          ledger phases
                        │
          admission (serial phase) + batched scoring (map phase) on the
          shared Runtime — identical accounting to every other plane,
          with measured step walls fed back to the switching policy
                        ▼
  Handle._finish ──▶ poll(handle) / drain() / Handle.result()

Two drive modes share the loop body:

* **inline / virtual clock** (default) — deterministic: ``poll``/``drain``
  advance the loop on the simulated axis; the closed-loop ``serve()``
  shim replays a trace through exactly this path, which is why it stays
  bit-identical to the pre-redesign engine.
* **threaded / wall clock** — ``start()`` spawns the background
  result-drain thread; ``submit`` is then safe from any thread and
  latencies are host wall seconds.

Scoring a query is row-independent (each basket's top-k never depends on
its batch neighbors), so async results are bit-identical to the
closed-loop oracle no matter how arrivals happen to batch — the property
``recommend --async --smoke`` pins under both switching policies.
"""
from __future__ import annotations

import threading
import time
from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.scheduler import TaskSpec
from repro.runtime import ExecLedger, LedgerTotals, MeasuredPhase
from repro.serving.admission import (BucketLadder, Handle, Query,
                                     RequestQueue, SloGovernor,
                                     VirtualClock, WallClock)
from repro.serving.cache import Recommendation, basket_key


@dataclass
class StepStats:
    """One drain-loop iteration (admission + scoring or a shed-only step)."""

    t_start: float
    t_done: float
    bucket: int = 0                 # 0 = shed-only step (nothing scored)
    batch_n: int = 0
    n_hits: int = 0
    n_misses: int = 0
    n_shed: int = 0


@dataclass
class AsyncServingReport(LedgerTotals):
    """Open-loop serving accounting: what sustained load actually costs.

    The async twin of ``ServingReport`` and a
    :class:`repro.runtime.PlaneReport`: the ledger slice is the source of
    truth for time/energy/switches; on top of it sit the open-loop
    numbers a closed-loop sweep cannot produce — sustained QPS over the
    arrival span, latency percentiles *under load*, shed count and slot
    occupancy.
    """

    backend: str = "ref"
    policy: str = "static"
    k: int = 0
    clock: str = "sim"              # latency domain: sim | wall
    slots: int = 0
    buckets: tuple = ()
    n_submitted: int = 0
    n_completed: int = 0
    n_shed: int = 0
    n_steps: int = 0
    bucket_counts: Dict[int, int] = field(default_factory=dict)
    slot_occupancy: float = 0.0     # mean admitted / slots per scoring step
    batch_fill: float = 0.0         # mean admitted / bucket per scoring step
    cache_hits: int = 0
    cache_misses: int = 0
    warm_wall_s: float = 0.0        # AOT ladder warmup (paid once, upfront)
    span_s: float = 0.0             # first arrival -> last completion
    p50_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    wall_time_s: float = 0.0
    index_version: int = 0
    constraint_flags: int = 0
    ledger: Optional[ExecLedger] = None

    @property
    def sustained_qps(self) -> float:
        """Completed requests per second over the open-loop span."""
        return self.n_completed / self.span_s if self.span_s > 0 else 0.0

    @property
    def shed_rate(self) -> float:
        return self.n_shed / self.n_submitted if self.n_submitted else 0.0

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def summary(self) -> str:
        buckets = "/".join(f"{b}:{c}" for b, c in
                           sorted(self.bucket_counts.items()))
        text = (
            f"AsyncServer: backend={self.backend} policy={self.policy} "
            f"k={self.k} clock={self.clock} slots={self.slots} "
            f"ladder={list(self.buckets)} index v{self.index_version}\n"
            f"  {self.n_completed}/{self.n_submitted} served "
            f"(+{self.n_shed} shed) in {self.n_steps} steps "
            f"(buckets {buckets or '-'}, fill {self.batch_fill:.2f}, "
            f"slot occupancy {self.slot_occupancy:.2f}) | cache "
            f"{self.cache_hits} hit / {self.cache_misses} miss "
            f"({self.hit_rate:.0%})\n"
            f"  sustained {self.sustained_qps:.1f} QPS over "
            f"{self.span_s:.4f}s (p50 {self.p50_latency_s:.4f}s, "
            f"p99 {self.p99_latency_s:.4f}s under load) | "
            f"{self.total_energy_j:.1f} J, {self.total_switches} core "
            f"switches | warmup {self.warm_wall_s:.3f}s, "
            f"wall {self.wall_time_s:.3f}s")
        if self.n_shed:
            text += (f"\n  SLO: shed {self.n_shed} request(s) "
                     f"({self.shed_rate:.1%}) at admission")
        if self.constraint_flags:
            text += (f"\n  WARNING: {self.constraint_flags} admission "
                     f"phase(s) ran on a core below their min_speed")
        return text


class AsyncServer:
    """Open request loop over a ``RecommendationEngine``'s data plane.

    The server owns admission; the engine contributes the compiled index,
    the result cache and the shared :class:`~repro.runtime.Runtime`.  One
    engine may back one live server plus any number of transient replay
    sessions (the ``serve()`` shim) — they serialize on the engine's
    single-threaded runtime, which only the drain side ever touches.
    """

    def __init__(self, engine, *, slots: Optional[int] = None,
                 slo_ms: Optional[float] = None,
                 coalesce_wait_s: Optional[float] = None,
                 clock: Union[VirtualClock, WallClock, None] = None,
                 warm: bool = True, name: str = "serve"):
        cfg = engine.config
        self.engine = engine
        self.name = name
        self.ladder = BucketLadder(engine._buckets)
        slots = cfg.slots if slots is None else slots
        if slots is None:
            slots = self.ladder.max_bucket
        if not 0 < slots <= self.ladder.max_bucket:
            raise ValueError(f"slots={slots} must be in [1, max bucket="
                             f"{self.ladder.max_bucket}]")
        self.slots = int(slots)
        slo_ms = cfg.slo_ms if slo_ms is None else slo_ms
        self.governor = SloGovernor(slo_ms / 1e3, self.ladder)
        self.coalesce_wait_s = (cfg.coalesce_wait_s if coalesce_wait_s is None
                                else coalesce_wait_s)
        self.clock = clock or VirtualClock()
        self.queue = RequestQueue()
        self._handles: List[Handle] = []      # submission order
        self._drained_upto = 0                # drain() exactly-once cursor
        self._steps: List[StepStats] = []
        self._rid = 0
        self._n_steps_taken = 0               # report-slice cursor
        self._hits0 = engine.cache.hits
        self._misses0 = engine.cache.misses
        self._ledger = ExecLedger()           # harvested per step
        self._warm_version = -1
        self.warm_wall_s = 0.0
        self._submit_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wall0 = time.perf_counter()
        if warm:
            self._warm_ladder()

    # ------------------------------------------------------------------
    # AOT bucket ladder warmup
    # ------------------------------------------------------------------
    def _warm_ladder(self) -> None:
        """Compile every rung's executable before the first request.

        One zero-basket execution per bucket populates the jit cache for
        that batch shape with the autotune-cache winner config — the
        open loop then never pays a compile mid-traffic.  Re-runs when
        the engine's index is refreshed (the shapes may have changed)."""
        eng = self.engine
        zero = np.zeros(eng.index.n_items, dtype=np.uint8)
        self.warm_wall_s += self.ladder.warm(
            lambda b: eng._score_batch([zero], b), time.perf_counter)
        self._warm_version = eng.index.version

    # ------------------------------------------------------------------
    # the submit / poll / drain surface
    # ------------------------------------------------------------------
    def submit(self, query, arrival_s: Optional[float] = None) -> Handle:
        """Enqueue one request; returns its :class:`Handle` immediately.

        Accepts :class:`Query` objects and ``{"items": ...}`` dicts.
        ``arrival_s`` defaults to the server clock's *now* (live
        traffic); replay callers pass explicit non-decreasing arrivals.
        Validation (id range, bitmap form) happens here, so a malformed
        request fails its caller at submit instead of poisoning the
        drain loop."""
        if not isinstance(query, (Query, Mapping)):
            raise TypeError(
                f"submit()/serve() take Query objects or dicts, not bare "
                f"{type(query).__name__} payloads — wrap the basket with "
                f"Query.of(...)")
        q = Query.of(query, arrival_s=arrival_s)
        bits = self.engine._as_bits(q.payload)
        with self._submit_lock:
            rid = q.rid if q.rid is not None else self._rid
            self._rid = max(self._rid, rid) + 1
            arrival = q.arrival_s
            if arrival is None:
                arrival = self.clock.now()
            handle = Handle(rid=rid, query=q, arrival_s=float(arrival),
                            bits=bits, key=basket_key(bits))
            self._handles.append(handle)
        self.queue.append(handle)
        return handle

    def poll(self, handle: Handle) -> Optional[Recommendation]:
        """Non-destructive progress check: the result when done, else None.

        On an inline (non-threaded) server, polling drives the loop until
        the handle resolves or the queue runs dry.  Raises
        :class:`ShedError` for a shed request — a dropped request must
        never read as "still computing"."""
        while not handle.done() and self._thread is None:
            if not self.step():
                break
        if handle.status == "shed":
            handle.result()                   # raises ShedError
        return handle._result if handle.done() else None

    def drain(self, timeout: Optional[float] = None) -> List[Handle]:
        """Deliver every outstanding request exactly once.

        Runs the loop to completion (inline) or waits for the drain
        thread (threaded, bounded by ``timeout`` per request), then
        returns the handles completed since the previous ``drain()`` in
        submission order.  Every submitted request appears in exactly one
        drain's return — the exactly-once delivery contract."""
        if self._thread is None:
            while self.step():
                pass
        else:
            for h in self._handles[self._drained_upto:]:
                h._event.wait(timeout)
        out = [h for h in self._handles[self._drained_upto:] if h.done()]
        self._drained_upto += len(out)
        return out

    # ------------------------------------------------------------------
    # the drain loop body
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One admission+scoring iteration; False when there is no work.

        Virtual clock: jumps to the next arrival when idle, then advances
        by the modeled admission + scoring time.  Wall clock: processes
        whatever has arrived by now."""
        nxt = self.queue.next_arrival()
        if nxt is None:
            return False
        now = self.clock.now()
        if now < nxt:
            if self.clock.domain != "sim":
                return False          # live mode: the future stays future
            now = self.clock.advance(nxt)
        ready = self.queue.take_ready(now, self.slots)
        if not ready:
            return False
        eng = self.engine
        if self._warm_version != eng.index.version:
            self._warm_ladder()       # index refresh invalidated the rungs

        admit, shed = self.governor.split(now, ready)
        rt = eng.runtime
        mark = rt.ledger.mark()
        step_i = len(self._steps)
        sim = self.clock.domain == "sim"
        t = now                       # simulated-axis step time
        if shed:
            # triage is real serial work: one phase covering this step's
            # rejects, priced through the scheduler like any admission
            _, rec = rt.run_serial(
                f"{self.name}-shed-{step_i}",
                cost=max(1.0, len(shed) * eng.config.admission_unit_cost),
                min_speed=eng.config.admission_min_speed, kind="shed")
            t += rec.sim_time_s
            # completion instants live in the clock's own domain: the
            # modeled axis when simulating, host wall when live
            t_shed = t if sim else self.clock.now()
            for h in shed:
                h._finish("shed", None, t_shed)

        stats = StepStats(t_start=now, t_done=t, n_shed=len(shed))
        if admit:
            t_wall0 = time.perf_counter()
            bucket = self.ladder.pick(len(admit))
            miss: List[Handle] = []
            hits = 0
            for h in admit:
                cached = eng.cache.get(h.key)
                if cached is not None:
                    h._result = cached        # finished below at t_done
                    hits += 1
                else:
                    miss.append(h)

            # serial admission/dispatch: best core runs, the rest gate off
            _, adm = rt.run_serial(
                f"{self.name}-admit-{step_i}",
                cost=max(1.0, bucket * eng.config.admission_unit_cost),
                min_speed=eng.config.admission_min_speed)
            t += adm.sim_time_s

            if miss:
                per_query_cost = (eng.config.score_unit_cost
                                  * eng.index.n_rows_padded
                                  * eng.index.n_items_padded)
                task = TaskSpec(f"{self.name}-score-{step_i}",
                                cost=bucket * per_query_cost, parallel=True,
                                n_tiles=bucket, family="serve-score")

                def execute(_asg, _costs, rows=miss, b=bucket):
                    t0 = time.perf_counter()
                    recs = eng._score_batch([h.bits for h in rows], b)
                    # measured step wall -> policy feedback + SLO EWMA
                    return MeasuredPhase(result=recs,
                                         wall_s=time.perf_counter() - t0)

                # each core spun up away from the admission core is a switch
                recs, score_rec = rt.run_phase(task, execute,
                                               spinup_from=adm.device)
                t += score_rec.sim_time_s
                for h, rec in zip(miss, recs):
                    h._result = rec
                    eng.cache.put(h.key, rec)

            t_done = t if sim else self.clock.now()
            for h in admit:
                h._finish("done", h._result, t_done)
            # the governor projects from what steps actually took, in the
            # clock's own domain (sim seconds or measured wall)
            self.ladder.observe(bucket, (t - now) if sim
                                else time.perf_counter() - t_wall0)
            stats.bucket = bucket
            stats.batch_n = len(admit)
            stats.n_hits = hits
            stats.n_misses = len(miss)
            stats.t_done = t_done

        self.clock.advance(t)
        for rec in rt.ledger.take_since(mark).phases:
            self._ledger.add(rec)     # harvest into this server's slice
        self._steps.append(stats)
        return True

    # ------------------------------------------------------------------
    # background result-drain thread (live mode)
    # ------------------------------------------------------------------
    def start(self) -> "AsyncServer":
        """Spawn the background drain thread (wall-clock live mode)."""
        if self._thread is not None:
            raise RuntimeError("drain thread already running")
        if self.clock.domain == "sim":
            self.clock = WallClock()
        self._stop.clear()
        self._thread = threading.Thread(target=self._drain_loop,
                                        name=f"{self.name}-drain",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the drain thread after it finishes the current step."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            if not self.queue.wait_nonempty(timeout=0.02):
                continue
            # bounded coalescing wait: let a concurrent burst fill the
            # slots, but never make a lone request wait for a full bucket
            if self.coalesce_wait_s > 0 and len(self.queue) < self.slots:
                self.queue.wait_depth(self.slots, self.coalesce_wait_s)
            self.step()

    def __enter__(self) -> "AsyncServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def take_report(self) -> AsyncServingReport:
        """Report over everything since the previous ``take_report()``.

        Takes ownership of the accumulated ledger slice and step stats
        (the long-lived server would otherwise grow without bound — same
        contract as ``ExecLedger.take_since``)."""
        eng = self.engine
        steps = self._steps[self._n_steps_taken:]
        self._n_steps_taken = len(self._steps)
        done = [h for h in self._handles if h.status == "done"]
        shed = [h for h in self._handles if h.status == "shed"]
        report = AsyncServingReport(
            backend=eng.backend, policy=eng.runtime.policy.name,
            k=eng.config.k, clock=self.clock.domain, slots=self.slots,
            buckets=self.ladder.buckets,
            n_submitted=len(self._handles), n_completed=len(done),
            n_shed=len(shed), n_steps=len(steps),
            warm_wall_s=self.warm_wall_s,
            index_version=eng.index.version,
            wall_time_s=time.perf_counter() - self._wall0)
        scored = [s for s in steps if s.batch_n]
        for s in scored:
            report.bucket_counts[s.bucket] = \
                report.bucket_counts.get(s.bucket, 0) + 1
        if scored:
            report.slot_occupancy = float(np.mean(
                [s.batch_n / self.slots for s in scored]))
            report.batch_fill = float(np.mean(
                [s.batch_n / s.bucket for s in scored]))
        report.cache_hits = eng.cache.hits - self._hits0
        report.cache_misses = eng.cache.misses - self._misses0
        self._hits0, self._misses0 = eng.cache.hits, eng.cache.misses
        finished = done + shed
        if finished:
            t0 = min(h.arrival_s for h in finished)
            t1 = max(h.done_s for h in finished)
            report.span_s = t1 - t0
        if done:
            lat = np.array([h.latency_s for h in done])
            report.p50_latency_s = float(np.percentile(lat, 50))
            report.p99_latency_s = float(np.percentile(lat, 99))
        report.ledger = self._ledger
        self._ledger = ExecLedger()
        report.constraint_flags = len(report.ledger.constraint_violations())
        return report
