"""Admission side of the async serving plane: requests, queue, ladder, SLO.

The continuous-batching loop (:mod:`repro.serving.server`) is assembled
from the pieces here, each one small enough to unit-test with a scripted
clock:

  :class:`Query`        — a request with a *stable id*: accepts plain
                          item-id lists, dicts and bitmap rows
  :class:`Handle`       — the Future-style receipt ``submit()`` returns
  :class:`RequestQueue` — thread-safe FIFO with arrival-time gating
  :class:`BucketLadder` — the AOT-pre-compiled batch-size ladder, plus
                          EWMA of *measured* step walls per bucket
  :class:`SloGovernor`  — projects each candidate's completion time from
                          the ladder's measured walls and sheds requests
                          that cannot meet the latency budget
  :class:`VirtualClock` / :class:`WallClock` — the two time domains: the
                          deterministic simulated axis every plane's
                          ledger uses, and host wall time for the
                          background drain thread

Admission states a request moves through (see docs/architecture.md):

  submitted ──▶ queued ──▶ admitted ──▶ scored ──▶ done
                   └──────▶ shed  (SLO governor, only when slo_ms is set)
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import (Any, Dict, List, Mapping, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.serving.cache import Recommendation


class ShedError(RuntimeError):
    """The SLO governor rejected this request at admission time."""


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Query:
    """One recommendation request with a stable request id.

    ``payload`` is the basket in any accepted form — a plain item-id
    sequence (``[3, 7]``), a 0/1 bitmap row over the item universe, or a
    dict ``{"items": [...], "id": ..., "arrival_s": ...}``.  The engine
    canonicalizes it exactly as before; ``Query`` adds identity (``rid``)
    and arrival time so a request can be tracked through the open loop.

    ``serve()``/``submit()`` accept only ``Query`` objects and dicts —
    the old positional form (a bare list/array straight to the server)
    was removed; wrap such payloads explicitly with :meth:`of`, which
    remains the one constructor for every accepted shape.
    """

    payload: Any
    rid: Optional[int] = None       # stable request id (server-assigned
    #                                 at submit when the caller sets none)
    arrival_s: Optional[float] = None

    @classmethod
    def of(cls, obj: Union["Query", Mapping, Sequence[int], np.ndarray],
           arrival_s: Optional[float] = None) -> "Query":
        """Coerce any accepted request form into a ``Query``."""
        if isinstance(obj, Query):
            if arrival_s is not None and obj.arrival_s is None:
                return Query(obj.payload, obj.rid, arrival_s)
            return obj
        if isinstance(obj, Mapping):
            extra = set(obj) - {"items", "id", "arrival_s"}
            if "items" not in obj or extra:
                raise ValueError(
                    f"dict queries need an 'items' key and allow only "
                    f"'id'/'arrival_s' besides it, got {sorted(obj)}")
            arr = obj.get("arrival_s", arrival_s)
            return cls(payload=obj["items"], rid=obj.get("id"),
                       arrival_s=arr)
        return cls(payload=obj, arrival_s=arrival_s)


class Handle:
    """Future-style receipt for one submitted request.

    ``status`` walks ``pending -> done | shed``; the terminal transition
    happens exactly once, on the server's drain loop.  ``result()`` blocks
    (threaded server) or raises if still pending (inline server — use
    ``server.poll(handle)``/``drain()`` to advance the loop first).
    """

    __slots__ = ("rid", "query", "arrival_s", "bits", "key", "status",
                 "done_s", "_result", "_event", "_delivered")

    def __init__(self, rid: int, query: Query, arrival_s: float,
                 bits: np.ndarray, key: bytes):
        self.rid = rid
        self.query = query
        self.arrival_s = arrival_s
        self.bits = bits            # canonical 0/1 vector (validated early)
        self.key = key              # cache key for the canonical basket
        self.status = "pending"
        self.done_s = 0.0           # completion instant on the server clock
        self._result: Optional[Recommendation] = None
        self._event = threading.Event()
        self._delivered = False     # consumed by drain() exactly once

    # -- server side ---------------------------------------------------
    def _finish(self, status: str, result: Optional[Recommendation],
                t_done: float) -> None:
        assert self.status == "pending", f"request {self.rid} finished twice"
        self._result = result
        self.done_s = t_done
        self.status = status
        self._event.set()

    # -- caller side ---------------------------------------------------
    def done(self) -> bool:
        return self.status != "pending"

    @property
    def latency_s(self) -> float:
        """Completion minus arrival on the server clock (0 while pending)."""
        return self.done_s - self.arrival_s if self.done() else 0.0

    def result(self, timeout: Optional[float] = None) -> Recommendation:
        if self.status == "pending" and timeout is not None:
            self._event.wait(timeout)
        if self.status == "shed":
            raise ShedError(f"request {self.rid} was shed by the SLO "
                            f"governor at t={self.done_s:.4f}s")
        if self.status != "done":
            raise RuntimeError(
                f"request {self.rid} is still pending — poll()/drain() the "
                f"server (inline mode) or pass a timeout (threaded mode)")
        return self._result


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------

class RequestQueue:
    """Thread-safe FIFO of pending handles with arrival-time gating.

    Submission order is service order; ``take_ready`` pops the contiguous
    head whose arrival times are ``<= now`` (up to ``limit`` — the slot
    count), which is exactly the closed-loop engine's admission scan, so
    the replay shim and the live loop share one discipline.
    """

    def __init__(self):
        self._q: "deque[Handle]" = deque()
        self._cond = threading.Condition()

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    def append(self, handle: Handle) -> None:
        with self._cond:
            self._q.append(handle)
            self._cond.notify_all()

    def next_arrival(self) -> Optional[float]:
        """Arrival instant of the FIFO head (None when empty)."""
        with self._cond:
            return self._q[0].arrival_s if self._q else None

    def take_ready(self, now: float, limit: int) -> List[Handle]:
        """Pop up to ``limit`` head requests whose arrival is ``<= now``."""
        out: List[Handle] = []
        with self._cond:
            while self._q and len(out) < limit \
                    and self._q[0].arrival_s <= now:
                out.append(self._q.popleft())
        return out

    def wait_nonempty(self, timeout: float) -> bool:
        """Block until the queue has work (or timeout); True when it has."""
        with self._cond:
            return self._cond.wait_for(lambda: bool(self._q), timeout)

    def wait_depth(self, depth: int, timeout: float) -> bool:
        """Coalescing wait: give concurrent arrivals a bounded chance to
        fill the batch; returns as soon as ``depth`` requests are queued.
        The bound is what guarantees no request waits for a full bucket."""
        with self._cond:
            return self._cond.wait_for(lambda: len(self._q) >= depth,
                                       timeout)


# ---------------------------------------------------------------------------
# the AOT bucket ladder
# ---------------------------------------------------------------------------

@dataclass
class BucketState:
    """Per-bucket executable + measurement state."""

    warm_wall_s: float = 0.0        # wall of the warmup execution (the
    #                                 compile+first-run cost paid upfront)
    ewma_step_s: float = 0.0        # EWMA of measured step durations
    n_steps: int = 0


class BucketLadder:
    """The ladder of pre-compiled per-bucket executables.

    ``warm()`` executes the scoring step once per bucket at startup so
    every rung's XLA executable (variant + tiles from the autotune cache)
    is compiled and resident before the first real request — no request
    ever pays a compile.  ``pick()`` coalesces: a partial batch runs on
    the smallest covering bucket instead of waiting to fill the largest.
    ``observe()`` keeps an EWMA of *measured* step durations per bucket —
    the SLO governor's projection source.
    """

    def __init__(self, buckets: Sequence[int], ewma_alpha: float = 0.3):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive: {buckets}")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1]: {ewma_alpha}")
        self.buckets: Tuple[int, ...] = tuple(sorted(set(int(b)
                                                         for b in buckets)))
        self.alpha = ewma_alpha
        self.state: Dict[int, BucketState] = {b: BucketState()
                                              for b in self.buckets}
        self.warmed = False

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def pick(self, batch_n: int) -> int:
        """Smallest bucket covering ``batch_n`` (bucket coalescing)."""
        if batch_n <= 0:
            raise ValueError(f"batch_n must be positive: {batch_n}")
        if batch_n > self.max_bucket:
            raise ValueError(f"batch of {batch_n} exceeds the ladder's "
                             f"largest bucket {self.max_bucket}")
        return next(b for b in self.buckets if b >= batch_n)

    def warm(self, step_fn, timer) -> float:
        """Pre-compile every rung: ``step_fn(bucket)`` once per bucket.

        ``timer`` is a zero-arg wall-seconds callable (injectable for
        tests).  Returns the total warmup wall and marks the ladder warm.
        """
        total = 0.0
        for b in self.buckets:
            t0 = timer()
            step_fn(b)
            wall = timer() - t0
            self.state[b].warm_wall_s = wall
            total += wall
        self.warmed = True
        return total

    def observe(self, bucket: int, step_s: float) -> None:
        """Feed one measured step duration into the bucket's EWMA."""
        st = self.state[bucket]
        st.ewma_step_s = (step_s if st.n_steps == 0 else
                          self.alpha * step_s
                          + (1 - self.alpha) * st.ewma_step_s)
        st.n_steps += 1

    def projected_step_s(self, bucket: int) -> float:
        """Best estimate of one step on this bucket (0 = nothing measured
        yet — the governor admits until the loop has real measurements)."""
        st = self.state[bucket]
        if st.n_steps:
            return st.ewma_step_s
        # fall back to the nearest measured rung, scaled by bucket ratio
        for b in self.buckets:
            if self.state[b].n_steps:
                return self.state[b].ewma_step_s * (bucket / b)
        return 0.0


# ---------------------------------------------------------------------------
# SLO-aware admission
# ---------------------------------------------------------------------------

class SloGovernor:
    """Shed-or-admit decisions from measured step walls.

    For each candidate the projected completion is ``(now - arrival)`` —
    the queueing delay already incurred — plus one projected scoring step
    on the chosen bucket.  A projection past ``slo_s`` sheds the request
    *at admission* (fail fast beats missing the budget after burning a
    slot).  ``slo_s <= 0`` disables shedding; with no measurements yet the
    ladder projects 0 and everything is admitted — the governor only ever
    acts on evidence.
    """

    def __init__(self, slo_s: float, ladder: BucketLadder):
        self.slo_s = slo_s
        self.ladder = ladder
        self.n_shed = 0

    def split(self, now: float, ready: List[Handle]
              ) -> Tuple[List[Handle], List[Handle]]:
        """Partition admitted-vs-shed, preserving FIFO order."""
        if self.slo_s <= 0 or not ready:
            return ready, []
        bucket = self.ladder.pick(len(ready))
        step = self.ladder.projected_step_s(bucket)
        admit, shed = [], []
        for h in ready:
            if (now - h.arrival_s) + step > self.slo_s:
                shed.append(h)
            else:
                admit.append(h)
        self.n_shed += len(shed)
        return admit, shed


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------

class VirtualClock:
    """The deterministic simulated axis (same units as the phase ledger).

    The server advances it by each step's modeled admission + scoring
    time, so queueing delay and batching gain show up in the latency
    percentiles exactly as in the closed-loop engine — and scripted tests
    control time completely.
    """

    domain = "sim"

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, t: float) -> float:
        """Move forward to ``t`` (never backwards)."""
        self._t = max(self._t, float(t))
        return self._t


class WallClock:
    """Host wall time, zeroed at construction (threaded server mode)."""

    domain = "wall"

    def __init__(self):
        import time
        self._perf = time.perf_counter
        self._t0 = self._perf()

    def now(self) -> float:
        return self._perf() - self._t0

    def advance(self, t: float) -> float:
        """Wall time advances itself; this is a no-op returning now()."""
        return self.now()
