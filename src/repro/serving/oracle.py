"""Brute-force recommendation oracle — plain Python over the raw rule list.

Implements the serving semantics (see ``repro.kernels.rule_match.ref``)
with no index, no kernel and no batching, so the engine's batched
data-plane output can be pinned to it *exactly* (confidences are compared
in float32, matching what the compiled index stores).  Used by
``tests/test_serving.py`` and the ``repro.launch.recommend --smoke`` gate.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.rules import Rule


def recommend_bruteforce(rules: Sequence[Rule], basket: Iterable[int],
                         k: int) -> List[Tuple[int, float]]:
    """Top-k (item, score) for one basket given as an item-id collection.

    score(j) = max confidence (as f32) over rules with antecedent ⊆ basket
    and j in the consequent; items already in the basket are excluded;
    ranking is (score desc, item id asc); only score > 0 entries returned.
    """
    basket_set = set(int(i) for i in basket)
    scores = {}
    for rule in rules:
        if not set(rule.antecedent) <= basket_set:
            continue
        c = float(np.float32(rule.confidence))
        for item in rule.consequent:
            if item in basket_set:
                continue
            if scores.get(item, 0.0) < c:
                scores[item] = c
    ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
    return [(int(i), float(s)) for i, s in ranked[:k] if s > 0.0]
