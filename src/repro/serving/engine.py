"""Micro-batching recommendation engine — the query side of the paper.

The mining pipeline's framing (serial phases to the best core, parallel
phases tiled over the heterogeneity profile, power charged for gating and
core switches) applies unchanged to serving:

  requests ──admission queue──▶ fixed batch buckets (pad-to-bucket)
     │            └─ serial dispatch phase  → Runtime.run_serial
     ├─ result cache probe (LRU on the canonical basket bitmap)
     ├─ batched scoring of the misses       → Runtime.run_phase
     │  (rule_match kernel: Pallas on TPU, jitted ref elsewhere)
     ▼
  per-request top-k + ServingReport (QPS, p50/p99, batch fill, cache,
  energy, switches) — the serving twin of PipelineReport

Pad-to-bucket is the same shape discipline as the mining data plane's
candidate bucketing: every batch is rounded up to a fixed bucket size so
XLA compiles one kernel per bucket, not one per traffic pattern.  The
simulated clock advances by (admission serial time + scoring makespan) per
batch, so queueing delay, batching gain and the scheduler policy all show
up in the latency percentiles.

Scheduling/accounting run on the shared :class:`repro.runtime.Runtime`:
each batch is one serial admission phase plus one parallel scoring phase
(every padded slot a schedulable tile), and the report's energy/switch
totals are read off the ledger slice — the same semantics as the mining
planes, including the spin-up rule that every core activated away from
the admission core is a core switch.

There is one serving loop: the continuous-batching
:class:`~repro.serving.server.AsyncServer`.  ``submit``/``poll``/``drain``
expose it directly for open-loop traffic; ``serve(queries)`` is a compat
shim that replays a closed trace through a transient session on the same
loop (virtual clock, slots = the largest bucket, SLO off) — which is why
its results, ledger slices and latency percentiles are bit-identical to
the pre-redesign engine.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler
from repro.kernels.rule_match.ops import rule_topk
from repro.pipeline.dataplane import resolve_backend
from repro.runtime import (ExecLedger, Runtime, SwitchingPolicy,
                           autotuned_costmodel)
from repro.serving.admission import Handle, Query
from repro.serving.cache import Recommendation, ResultCache
from repro.serving.index import RuleIndex

# Any accepted request form: a Query object or a dict with an "items" key.
# Bare item-id sequences / bitmap rows must be wrapped through Query.of —
# the positional raw-basket form was removed from serve()/submit().
QueryLike = Union[Query, Dict]


@dataclass(frozen=True)
class ServingConfig:
    """Knobs for the online engine (mirrors PipelineConfig for mining)."""

    k: int = 5                      # recommendations per query
    batch_buckets: Tuple[int, ...] = (1, 8, 64)   # admission coalescing sizes
    data_plane: str = "auto"        # auto | pallas | ref
    interpret: Optional[bool] = None  # force Pallas interpret mode (tests)
    # Kernel autotuning: True = the checked-in winner cache picks the
    # rule-match variant + tile shapes (and, under the costmodel policy,
    # its measured walls replace the roofline constants); False = defaults.
    autotune: bool = True
    cache_size: int = 4096          # LRU entries; 0 disables caching
    policy: str = "static"          # switching: static | dynamic | costmodel
    split: str = "lpt"              # tile split for the scoring phase
    power: str = "cpu"              # cpu | tpu_v5e | none
    # Work-unit cost model (same byte-flavored units as the mining phases):
    # admission charges per batch slot, scoring per slot scaled by index
    # size (each query is matched against every rule row).
    admission_unit_cost: float = 8.0
    score_unit_cost: float = 1.0 / 128.0
    # Required core speed for the serial admission phase: when no core
    # satisfies it, assign_serial falls back to the fastest core and flags
    # the phase (surfaced as ServingReport.constraint_violations).
    admission_min_speed: float = 0.0
    # Async serving (the submit/poll/drain surface and `recommend --async`):
    # slots bounds how many queued requests one drain-loop step admits
    # (None = the largest bucket); slo_ms > 0 arms the SLO governor, which
    # sheds requests whose projected completion misses the budget;
    # coalesce_wait_s bounds how long the threaded drain loop lets a burst
    # accumulate before scoring a partial batch (never strands a request).
    slots: Optional[int] = None
    slo_ms: float = 0.0
    coalesce_wait_s: float = 0.002


@dataclass
class ServingReport:
    """Accounting for one ``serve()`` call (the serving PipelineReport)."""

    backend: str
    policy: str                     # switching policy name
    k: int
    split: str = "lpt"
    n_queries: int = 0
    n_batches: int = 0
    bucket_counts: Dict[int, int] = field(default_factory=dict)
    batch_fill: float = 0.0         # mean true-requests / bucket-size, <= 1
    cache_hits: int = 0
    cache_misses: int = 0
    sim_time_s: float = 0.0         # simulated clock at last completion
    wall_time_s: float = 0.0
    p50_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    energy_j: float = 0.0
    switches: int = 0
    index_rows: int = 0
    index_version: int = 0
    constraint_violations: int = 0  # admission phases below their min_speed
    ledger: Optional[ExecLedger] = None   # this call's phase records

    # PlaneReport totals, read off the attached ledger slice.  Note
    # total_time_s sums phase time only; sim_time_s additionally spans the
    # arrival gaps the admission queue sat idle.
    @property
    def total_time_s(self) -> float:
        return self.ledger.total_time_s if self.ledger else 0.0

    @property
    def total_energy_j(self) -> float:
        return self.ledger.total_energy_j if self.ledger else 0.0

    @property
    def total_switches(self) -> int:
        return self.ledger.total_switches if self.ledger else 0

    @property
    def qps(self) -> float:
        """Simulated queries/second (work-unit clock, policy-sensitive)."""
        return self.n_queries / self.sim_time_s if self.sim_time_s > 0 else 0.0

    @property
    def wall_qps(self) -> float:
        return (self.n_queries / self.wall_time_s
                if self.wall_time_s > 0 else 0.0)

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def summary(self) -> str:
        buckets = "/".join(f"{b}:{c}" for b, c in
                           sorted(self.bucket_counts.items()))
        text = (
            f"RecommendationEngine: backend={self.backend} "
            f"policy={self.policy} split={self.split} k={self.k} "
            f"index_rows={self.index_rows} v{self.index_version}\n"
            f"  {self.n_queries} queries in {self.n_batches} batches "
            f"(buckets {buckets}, fill {self.batch_fill:.2f}) | cache "
            f"{self.cache_hits} hit / {self.cache_misses} miss "
            f"({self.hit_rate:.0%})\n"
            f"  simulated {self.sim_time_s:.4f}s = {self.qps:.1f} QPS "
            f"(p50 {self.p50_latency_s:.4f}s, p99 {self.p99_latency_s:.4f}s) "
            f"| {self.energy_j:.1f} J, {self.switches} core switches | "
            f"wall {self.wall_time_s:.3f}s = {self.wall_qps:.0f} QPS")
        if self.constraint_violations:
            text += (f"\n  WARNING: {self.constraint_violations} admission "
                     f"phase(s) ran on a core below their min_speed")
        return text


class RecommendationEngine:
    """Serves "given this basket, which items next?" from a compiled index."""

    def __init__(self, index: RuleIndex,
                 profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[ServingConfig] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None,
                 policy: Union[str, SwitchingPolicy, None] = None):
        self.config = config or ServingConfig()
        cfg = self.config
        if not cfg.batch_buckets or any(b <= 0 for b in cfg.batch_buckets):
            raise ValueError(f"batch_buckets must be positive: "
                             f"{cfg.batch_buckets}")
        self._buckets = tuple(sorted(set(int(b) for b in cfg.batch_buckets)))
        if not 0 < cfg.k <= index.n_items:
            raise ValueError(f"k={cfg.k} must be in [1, n_items="
                             f"{index.n_items}]")
        self.profile = profile or HeterogeneityProfile.paper()
        policy = policy if policy is not None else cfg.policy
        if policy == "costmodel" and cfg.autotune:
            # measured kernel walls replace the datasheet constants
            policy = autotuned_costmodel("rule_match")
        self.runtime = Runtime(
            self.profile,
            policy=policy,
            split=cfg.split,
            power=power if power is not None else cfg.power,
            scheduler=scheduler)
        self.scheduler = self.runtime.scheduler
        self.power = self.runtime.power
        self.backend = resolve_backend(cfg.data_plane)
        self.cache = ResultCache(cfg.cache_size)
        self._server = None           # persistent AsyncServer, built lazily
        self.index: RuleIndex = None  # set by refresh()
        self.refresh(index)

    # ------------------------------------------------------------------
    def refresh(self, index: RuleIndex) -> RuleIndex:
        """Atomically swap in a (re)built index and invalidate the cache.

        The version is bumped past the live index's if the new build does
        not already exceed it, so cache generations are totally ordered.
        """
        if self.index is not None and index.version <= self.index.version:
            index = dataclasses.replace(index,
                                        version=self.index.version + 1)
        # device-resident once: every batch reuses these arrays
        self._dev = {
            "ante": jnp.asarray(index.ante),
            "sizes": jnp.asarray(index.sizes),
            "conf": jnp.asarray(index.conf),
            "cons": jnp.asarray(index.cons),
        }
        self.index = index          # single assignment = the atomic swap
        self.cache.clear()
        return index

    # ------------------------------------------------------------------
    def _as_bits(self, query: QueryLike) -> np.ndarray:
        """Canonical 0/1 vector over the true item universe.

        Array inputs (numpy/jax rows) of full basket length are bitmaps;
        Python sequences (list/tuple/set) are always item-id collections —
        a list of 0/1 values is NOT treated as a bitmap, since a two-item
        basket [0, 1] would be indistinguishable from one.  ``Query``
        objects and ``{"items": ...}`` dicts are unwrapped first.
        """
        if isinstance(query, (Query, dict)):
            query = Query.of(query).payload
        n_items = self.index.n_items
        if not isinstance(query, (list, tuple, set, frozenset, range)):
            query = np.asarray(query)     # jax/device arrays -> host bitmap
        if isinstance(query, np.ndarray) and query.ndim == 1 and \
                query.shape[0] in (n_items, self.index.n_items_padded):
            if query.size and not ((query == 0) | (query == 1)).all():
                raise ValueError("bitmap queries must contain only 0/1")
            if query[n_items:].any():
                raise ValueError(f"bitmap query sets items beyond the index "
                                 f"universe [0, {n_items})")
            return query[:n_items].astype(np.uint8)
        bits = np.zeros(n_items, dtype=np.uint8)
        ids = list(query)
        if ids:
            idx = np.asarray(ids, dtype=np.int64)
            if idx.min() < 0 or idx.max() >= n_items:
                raise ValueError(f"query item ids must be in [0, {n_items})")
            bits[idx] = 1
        return bits

    def _score_batch(self, rows: List[np.ndarray],
                     bucket: int) -> List[Recommendation]:
        """Run the rule-match data plane on a pad-to-bucket query block."""
        cfg = self.config
        Q = np.zeros((bucket, self.index.n_items_padded), dtype=np.uint8)
        for r, bits in enumerate(rows):
            Q[r, :self.index.n_items] = bits
        items, scores = rule_topk(
            Q, self._dev["ante"], self._dev["sizes"], self._dev["conf"],
            self._dev["cons"], k=cfg.k, n_items=self.index.n_items,
            backend=self.backend, interpret=cfg.interpret,
            tuning=None if cfg.autotune else False)
        items = np.asarray(items)
        scores = np.asarray(scores)
        return [[(int(i), float(s)) for i, s in zip(items[r], scores[r])
                 if s > 0.0] for r in range(len(rows))]

    # ------------------------------------------------------------------
    # the async surface: submit / poll / drain on a persistent open loop
    # ------------------------------------------------------------------
    @property
    def server(self):
        """The engine's persistent :class:`~repro.serving.server.AsyncServer`.

        Created lazily in inline virtual-clock mode (``poll``/``drain``
        advance the loop deterministically); call ``.start()`` on it — or
        use it as a context manager — for threaded wall-clock serving.
        """
        if self._server is None:
            from repro.serving.server import AsyncServer
            self._server = AsyncServer(self)
        return self._server

    def submit(self, query: QueryLike,
               arrival_s: Optional[float] = None) -> Handle:
        """Enqueue one request on the open loop; returns its Handle."""
        return self.server.submit(query, arrival_s=arrival_s)

    def poll(self, handle: Handle) -> Optional[Recommendation]:
        """Progress the open loop; the handle's result when done, else None."""
        return self.server.poll(handle)

    def drain(self, timeout: Optional[float] = None) -> List[Handle]:
        """Run the open loop dry; handles completed since the last drain."""
        return self.server.drain(timeout=timeout)

    # ------------------------------------------------------------------
    # the closed-loop surface (a replay session on the same loop)
    # ------------------------------------------------------------------
    def recommend(self, query: QueryLike) -> Recommendation:
        """Single-query convenience path (cached, batch of one)."""
        results, _ = self.serve([query])
        return results[0]

    def serve(self, queries: Sequence[QueryLike],
              arrival_s: Optional[Sequence[float]] = None
              ) -> Tuple[List[Recommendation], ServingReport]:
        """Replay a query trace through the admission queue.

        arrival_s (optional, non-decreasing, simulated seconds) drives the
        queueing model; default is all-at-once.  Returns per-request top-k
        recommendations (input order) and the ServingReport.

        Compat shim: the trace runs through a transient
        :class:`~repro.serving.server.AsyncServer` session (virtual clock,
        slots = largest bucket, SLO governor off, no warmup) whose step
        semantics match the original closed loop exactly — per-row scoring
        is batch-independent, so results and accounting are bit-identical.
        """
        cfg = self.config
        rt = self.runtime
        t_wall = time.perf_counter()
        # a run that raised mid-way (invariant check, scoring error) leaves
        # orphaned records; this plane owns its runtime, so anything still
        # live belongs to no report — drop it before marking
        rt.ledger.take_since(0)
        n = len(queries)
        if arrival_s is None:
            arrival = np.zeros(n)
        else:
            arrival = np.asarray(arrival_s, dtype=np.float64)
            if arrival.shape != (n,):
                raise ValueError(f"arrival_s must have one entry per query: "
                                 f"{arrival.shape} vs {n}")
            if n and (np.diff(arrival) < 0).any():
                raise ValueError("arrival_s must be non-decreasing")

        from repro.serving.server import AsyncServer
        session = AsyncServer(self, slots=self._buckets[-1], slo_ms=0.0,
                              coalesce_wait_s=0.0, warm=False)
        # submit everything up front (validation happens here, before any
        # phase runs — same all-or-nothing contract as the original loop),
        # then run the session dry on the virtual clock
        handles = [session.submit(q, arrival_s=float(arrival[j]))
                   for j, q in enumerate(queries)]
        session.drain()
        arep = session.take_report()

        results = [h.result() for h in handles]
        report = ServingReport(
            backend=self.backend, policy=rt.policy.name, split=rt.split,
            k=cfg.k, n_queries=n, index_rows=self.index.n_rows,
            index_version=self.index.version, n_batches=arep.n_steps,
            bucket_counts=dict(arep.bucket_counts),
            batch_fill=arep.batch_fill, cache_hits=arep.cache_hits,
            cache_misses=arep.cache_misses,
            sim_time_s=session.clock.now(), ledger=arep.ledger)
        report.energy_j = report.ledger.total_energy_j
        report.switches = report.ledger.total_switches
        report.constraint_violations = \
            len(report.ledger.constraint_violations())
        if n:
            latencies = np.array([h.latency_s for h in handles])
            report.p50_latency_s = float(np.percentile(latencies, 50))
            report.p99_latency_s = float(np.percentile(latencies, 99))
        report.wall_time_s = time.perf_counter() - t_wall
        return results, report
