"""Micro-batching recommendation engine — the query side of the paper.

The mining pipeline's framing (serial phases to the best core, parallel
phases tiled over the heterogeneity profile, power charged for gating and
core switches) applies unchanged to serving:

  requests ──admission queue──▶ fixed batch buckets (pad-to-bucket)
     │            └─ serial dispatch phase  → Runtime.run_serial
     ├─ result cache probe (LRU on the canonical basket bitmap)
     ├─ batched scoring of the misses       → Runtime.run_phase
     │  (rule_match kernel: Pallas on TPU, jitted ref elsewhere)
     ▼
  per-request top-k + ServingReport (QPS, p50/p99, batch fill, cache,
  energy, switches) — the serving twin of PipelineReport

Pad-to-bucket is the same shape discipline as the mining data plane's
candidate bucketing: every batch is rounded up to a fixed bucket size so
XLA compiles one kernel per bucket, not one per traffic pattern.  The
simulated clock advances by (admission serial time + scoring makespan) per
batch, so queueing delay, batching gain and the scheduler policy all show
up in the latency percentiles.

Scheduling/accounting run on the shared :class:`repro.runtime.Runtime`:
each batch is one serial admission phase plus one parallel scoring phase
(every padded slot a schedulable tile), and the report's energy/switch
totals are read off the ledger slice — the same semantics as the mining
planes, including the spin-up rule that every core activated away from
the admission core is a core switch.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.kernels.rule_match.ops import rule_topk
from repro.pipeline.dataplane import resolve_backend
from repro.runtime import (ExecLedger, MeasuredPhase, Runtime,
                           SwitchingPolicy, autotuned_costmodel)
from repro.serving.cache import Recommendation, ResultCache, basket_key
from repro.serving.index import RuleIndex

Query = Union[np.ndarray, Sequence[int]]


@dataclass(frozen=True)
class ServingConfig:
    """Knobs for the online engine (mirrors PipelineConfig for mining)."""

    k: int = 5                      # recommendations per query
    batch_buckets: Tuple[int, ...] = (1, 8, 64)   # admission coalescing sizes
    data_plane: str = "auto"        # auto | pallas | ref
    interpret: Optional[bool] = None  # force Pallas interpret mode (tests)
    # Kernel autotuning: True = the checked-in winner cache picks the
    # rule-match variant + tile shapes (and, under the costmodel policy,
    # its measured walls replace the roofline constants); False = defaults.
    autotune: bool = True
    cache_size: int = 4096          # LRU entries; 0 disables caching
    policy: str = "static"          # switching: static | dynamic | costmodel
    split: str = "lpt"              # tile split for the scoring phase
    power: str = "cpu"              # cpu | tpu_v5e | none
    # Work-unit cost model (same byte-flavored units as the mining phases):
    # admission charges per batch slot, scoring per slot scaled by index
    # size (each query is matched against every rule row).
    admission_unit_cost: float = 8.0
    score_unit_cost: float = 1.0 / 128.0
    # Required core speed for the serial admission phase: when no core
    # satisfies it, assign_serial falls back to the fastest core and flags
    # the phase (surfaced as ServingReport.constraint_violations).
    admission_min_speed: float = 0.0


@dataclass
class ServingReport:
    """Accounting for one ``serve()`` call (the serving PipelineReport)."""

    backend: str
    policy: str                     # switching policy name
    k: int
    split: str = "lpt"
    n_queries: int = 0
    n_batches: int = 0
    bucket_counts: Dict[int, int] = field(default_factory=dict)
    batch_fill: float = 0.0         # mean true-requests / bucket-size, <= 1
    cache_hits: int = 0
    cache_misses: int = 0
    sim_time_s: float = 0.0         # simulated clock at last completion
    wall_time_s: float = 0.0
    p50_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    energy_j: float = 0.0
    switches: int = 0
    index_rows: int = 0
    index_version: int = 0
    constraint_violations: int = 0  # admission phases below their min_speed
    ledger: Optional[ExecLedger] = None   # this call's phase records

    @property
    def qps(self) -> float:
        """Simulated queries/second (work-unit clock, policy-sensitive)."""
        return self.n_queries / self.sim_time_s if self.sim_time_s > 0 else 0.0

    @property
    def wall_qps(self) -> float:
        return (self.n_queries / self.wall_time_s
                if self.wall_time_s > 0 else 0.0)

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def summary(self) -> str:
        buckets = "/".join(f"{b}:{c}" for b, c in
                           sorted(self.bucket_counts.items()))
        text = (
            f"RecommendationEngine: backend={self.backend} "
            f"policy={self.policy} split={self.split} k={self.k} "
            f"index_rows={self.index_rows} v{self.index_version}\n"
            f"  {self.n_queries} queries in {self.n_batches} batches "
            f"(buckets {buckets}, fill {self.batch_fill:.2f}) | cache "
            f"{self.cache_hits} hit / {self.cache_misses} miss "
            f"({self.hit_rate:.0%})\n"
            f"  simulated {self.sim_time_s:.4f}s = {self.qps:.1f} QPS "
            f"(p50 {self.p50_latency_s:.4f}s, p99 {self.p99_latency_s:.4f}s) "
            f"| {self.energy_j:.1f} J, {self.switches} core switches | "
            f"wall {self.wall_time_s:.3f}s = {self.wall_qps:.0f} QPS")
        if self.constraint_violations:
            text += (f"\n  WARNING: {self.constraint_violations} admission "
                     f"phase(s) ran on a core below their min_speed")
        return text


class RecommendationEngine:
    """Serves "given this basket, which items next?" from a compiled index."""

    def __init__(self, index: RuleIndex,
                 profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[ServingConfig] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None,
                 policy: Union[str, SwitchingPolicy, None] = None):
        self.config = config or ServingConfig()
        cfg = self.config
        if not cfg.batch_buckets or any(b <= 0 for b in cfg.batch_buckets):
            raise ValueError(f"batch_buckets must be positive: "
                             f"{cfg.batch_buckets}")
        self._buckets = tuple(sorted(set(int(b) for b in cfg.batch_buckets)))
        if not 0 < cfg.k <= index.n_items:
            raise ValueError(f"k={cfg.k} must be in [1, n_items="
                             f"{index.n_items}]")
        self.profile = profile or HeterogeneityProfile.paper()
        policy = policy if policy is not None else cfg.policy
        if policy == "costmodel" and cfg.autotune:
            # measured kernel walls replace the datasheet constants
            policy = autotuned_costmodel("rule_match")
        self.runtime = Runtime(
            self.profile,
            policy=policy,
            split=cfg.split,
            power=power if power is not None else cfg.power,
            scheduler=scheduler)
        self.scheduler = self.runtime.scheduler
        self.power = self.runtime.power
        self.backend = resolve_backend(cfg.data_plane)
        self.cache = ResultCache(cfg.cache_size)
        self.index: RuleIndex = None  # set by refresh()
        self.refresh(index)

    # ------------------------------------------------------------------
    def refresh(self, index: RuleIndex) -> RuleIndex:
        """Atomically swap in a (re)built index and invalidate the cache.

        The version is bumped past the live index's if the new build does
        not already exceed it, so cache generations are totally ordered.
        """
        if self.index is not None and index.version <= self.index.version:
            index = dataclasses.replace(index,
                                        version=self.index.version + 1)
        # device-resident once: every batch reuses these arrays
        self._dev = {
            "ante": jnp.asarray(index.ante),
            "sizes": jnp.asarray(index.sizes),
            "conf": jnp.asarray(index.conf),
            "cons": jnp.asarray(index.cons),
        }
        self.index = index          # single assignment = the atomic swap
        self.cache.clear()
        return index

    # ------------------------------------------------------------------
    def _as_bits(self, query: Query) -> np.ndarray:
        """Canonical 0/1 vector over the true item universe.

        Array inputs (numpy/jax rows) of full basket length are bitmaps;
        Python sequences (list/tuple/set) are always item-id collections —
        a list of 0/1 values is NOT treated as a bitmap, since a two-item
        basket [0, 1] would be indistinguishable from one.
        """
        n_items = self.index.n_items
        if not isinstance(query, (list, tuple, set, frozenset, range)):
            query = np.asarray(query)     # jax/device arrays -> host bitmap
        if isinstance(query, np.ndarray) and query.ndim == 1 and \
                query.shape[0] in (n_items, self.index.n_items_padded):
            if query.size and not ((query == 0) | (query == 1)).all():
                raise ValueError("bitmap queries must contain only 0/1")
            if query[n_items:].any():
                raise ValueError(f"bitmap query sets items beyond the index "
                                 f"universe [0, {n_items})")
            return query[:n_items].astype(np.uint8)
        bits = np.zeros(n_items, dtype=np.uint8)
        ids = list(query)
        if ids:
            idx = np.asarray(ids, dtype=np.int64)
            if idx.min() < 0 or idx.max() >= n_items:
                raise ValueError(f"query item ids must be in [0, {n_items})")
            bits[idx] = 1
        return bits

    def _score_batch(self, rows: List[np.ndarray],
                     bucket: int) -> List[Recommendation]:
        """Run the rule-match data plane on a pad-to-bucket query block."""
        cfg = self.config
        Q = np.zeros((bucket, self.index.n_items_padded), dtype=np.uint8)
        for r, bits in enumerate(rows):
            Q[r, :self.index.n_items] = bits
        items, scores = rule_topk(
            Q, self._dev["ante"], self._dev["sizes"], self._dev["conf"],
            self._dev["cons"], k=cfg.k, n_items=self.index.n_items,
            backend=self.backend, interpret=cfg.interpret,
            tuning=None if cfg.autotune else False)
        items = np.asarray(items)
        scores = np.asarray(scores)
        return [[(int(i), float(s)) for i, s in zip(items[r], scores[r])
                 if s > 0.0] for r in range(len(rows))]

    # ------------------------------------------------------------------
    def recommend(self, query: Query) -> Recommendation:
        """Single-query convenience path (cached, batch of one)."""
        results, _ = self.serve([query])
        return results[0]

    def serve(self, queries: Sequence[Query],
              arrival_s: Optional[Sequence[float]] = None
              ) -> Tuple[List[Recommendation], ServingReport]:
        """Replay a query trace through the admission queue.

        arrival_s (optional, non-decreasing, simulated seconds) drives the
        queueing model; default is all-at-once.  Returns per-request top-k
        recommendations (input order) and the ServingReport.
        """
        cfg = self.config
        rt = self.runtime
        t_wall = time.perf_counter()
        # a run that raised mid-way (invariant check, scoring error) leaves
        # orphaned records; this plane owns its runtime, so anything still
        # live belongs to no report — drop it before marking
        rt.ledger.take_since(0)
        mark = rt.ledger.mark()
        bits = [self._as_bits(q) for q in queries]
        keys = [basket_key(b) for b in bits]
        n = len(bits)
        if arrival_s is None:
            arrival = np.zeros(n)
        else:
            arrival = np.asarray(arrival_s, dtype=np.float64)
            if arrival.shape != (n,):
                raise ValueError(f"arrival_s must have one entry per query: "
                                 f"{arrival.shape} vs {n}")
            if n and (np.diff(arrival) < 0).any():
                raise ValueError("arrival_s must be non-decreasing")

        report = ServingReport(backend=self.backend, policy=rt.policy.name,
                               split=rt.split, k=cfg.k,
                               n_queries=n, index_rows=self.index.n_rows,
                               index_version=self.index.version)
        results: List[Optional[Recommendation]] = [None] * n
        latencies = np.zeros(n)
        hits0, misses0 = self.cache.hits, self.cache.misses
        fills: List[float] = []
        max_bucket = self._buckets[-1]
        per_query_cost = (cfg.score_unit_cost * self.index.n_rows_padded
                          * self.index.n_items_padded)
        t = 0.0
        i = 0
        while i < n:
            t = max(t, arrival[i])
            avail = i
            while avail < n and arrival[avail] <= t:
                avail += 1
            batch_n = min(avail - i, max_bucket)
            bucket = next(b for b in self._buckets if b >= batch_n)

            miss_idx = []
            for j in range(i, i + batch_n):
                cached = self.cache.get(keys[j])
                if cached is not None:
                    results[j] = cached
                else:
                    miss_idx.append(j)

            # serial admission/dispatch: best core runs, the rest gate off
            _, adm = rt.run_serial(
                f"serve-admit-{report.n_batches}",
                cost=max(1.0, bucket * cfg.admission_unit_cost),
                min_speed=cfg.admission_min_speed)
            t_serial = adm.sim_time_s

            makespan = 0.0
            if miss_idx:
                # parallel scoring: the padded bucket is what the data plane
                # runs, so every slot is a schedulable tile
                task = TaskSpec(f"serve-score-{report.n_batches}",
                                cost=bucket * per_query_cost, parallel=True,
                                n_tiles=bucket, family="serve-score")

                def execute(_asg, _costs, rows=miss_idx, b=bucket):
                    return MeasuredPhase(result=self._score_batch(
                        [bits[j] for j in rows], b))

                # each core spun up away from the admission core is a switch
                recs, score_rec = rt.run_phase(task, execute,
                                               spinup_from=adm.device)
                makespan = score_rec.sim_time_s
                for j, rec in zip(miss_idx, recs):
                    results[j] = rec
                    self.cache.put(keys[j], rec)

            t_done = t + t_serial + makespan
            for j in range(i, i + batch_n):
                latencies[j] = t_done - arrival[j]
            fills.append(batch_n / bucket)
            report.bucket_counts[bucket] = \
                report.bucket_counts.get(bucket, 0) + 1
            report.n_batches += 1
            t = t_done
            i += batch_n

        report.cache_hits = self.cache.hits - hits0
        report.cache_misses = self.cache.misses - misses0
        report.sim_time_s = t
        report.batch_fill = float(np.mean(fills)) if fills else 0.0
        if n:
            report.p50_latency_s = float(np.percentile(latencies, 50))
            report.p99_latency_s = float(np.percentile(latencies, 99))
        report.ledger = rt.ledger.take_since(mark)
        report.energy_j = report.ledger.total_energy_j
        report.switches = report.ledger.total_switches
        report.constraint_violations = \
            len(report.ledger.constraint_violations())
        report.wall_time_s = time.perf_counter() - t_wall
        return results, report
