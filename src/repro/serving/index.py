"""Compiled rule index: ``List[Rule]`` lowered to dense, kernel-shaped arrays.

The mined rule list is a Python object that dies with the process; serving
needs the opposite — a deterministic, device-friendly layout the batched
rule-match kernel can consume directly:

  ante    uint8[Rp, Ip]   antecedent bitmaps, same item-minor / 128-lane
                          word layout as the mining transaction bitmaps
  sizes   f32[Rp]         |antecedent| per row (-1 on padded rows: an
                          all-zero row would subset-match every basket)
  cons    int32[Rp]       consequent item id per row (Ip on padded rows —
                          a dummy max-segment the ops wrapper slices away)
  conf / lift / support   f32[Rp] parallel scoring arrays (0 on padding)

One *row* is one (rule, consequent-item) pair: a rule whose consequent has
several items contributes one row per item, each carrying the rule's
statistics, and duplicate (antecedent, item) pairs keep the best row.  The
row order is a total order (confidence desc, support desc, lift desc,
antecedent, consequent — the ``generate_rules`` key) so the same rule set
always compiles to the same arrays — byte-identical across processes,
which save/load and the result cache rely on.

Rows are padded ("bucketed") to a multiple of ``r_bucket`` (kernel lanes)
and items to 128 lanes, so every index built from the same corpus shape
hits the same jit-cache entry.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import store as ckpt_store
from repro.core.rules import Rule

_ARRAY_FIELDS = ("ante", "sizes", "conf", "lift", "support", "cons")


@dataclass(frozen=True)
class RuleIndex:
    """Immutable compiled form of a mined rule set (see module docstring)."""

    ante: np.ndarray        # uint8 [Rp, Ip]
    sizes: np.ndarray       # float32 [Rp], -1 on padding
    conf: np.ndarray        # float32 [Rp]
    lift: np.ndarray        # float32 [Rp]
    support: np.ndarray     # float32 [Rp]
    cons: np.ndarray        # int32 [Rp], Ip on padding
    n_rows: int             # true (rule, consequent-item) rows
    n_rules: int            # source rules before expansion
    n_items: int            # true item-universe size before lane padding
    version: int = 0        # monotonically bumped by refresh()

    # ------------------------------------------------------------------
    @property
    def n_rows_padded(self) -> int:
        return int(self.ante.shape[0])

    @property
    def n_items_padded(self) -> int:
        return int(self.ante.shape[1])

    @property
    def nbytes(self) -> int:
        return sum(getattr(self, f).nbytes for f in _ARRAY_FIELDS)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, rules: Sequence[Rule], n_items: int, *,
              r_bucket: int = 128, version: int = 0) -> "RuleIndex":
        """Deterministic lowering (stable total order; see module docstring)."""
        if n_items <= 0:
            raise ValueError(f"n_items must be positive, got {n_items}")
        if r_bucket <= 0 or r_bucket % 128:
            raise ValueError(
                "r_bucket must be a positive multiple of 128 (kernel lanes)")
        rows: List[Tuple[Tuple[int, ...], int, float, float, float]] = []
        for rule in rules:
            bad = [i for i in rule.antecedent + rule.consequent
                   if not 0 <= i < n_items]
            if bad:
                raise ValueError(f"rule {rule} references item ids {bad} "
                                 f"outside [0, {n_items})")
            for item in rule.consequent:
                rows.append((rule.antecedent, item, rule.confidence,
                             rule.lift, rule.support))
        # same total order as generate_rules, extended to expanded rows
        rows.sort(key=lambda t: (-t[2], -t[4], -t[3], t[0], t[1]))
        seen = set()
        dedup = []
        for row in rows:
            key = (row[0], row[1])
            if key not in seen:          # first occurrence is the best row
                seen.add(key)
                dedup.append(row)

        n_rows = len(dedup)
        Rp = max(r_bucket, n_rows + (-n_rows) % r_bucket)
        Ip = n_items + (-n_items) % 128
        ante = np.zeros((Rp, Ip), dtype=np.uint8)
        sizes = np.full(Rp, -1.0, dtype=np.float32)
        conf = np.zeros(Rp, dtype=np.float32)
        lift = np.zeros(Rp, dtype=np.float32)
        support = np.zeros(Rp, dtype=np.float32)
        cons = np.full(Rp, Ip, dtype=np.int32)
        for r, (a, item, c, lf, sp) in enumerate(dedup):
            ante[r, list(a)] = 1
            sizes[r] = len(a)
            conf[r] = c
            lift[r] = lf
            support[r] = sp
            cons[r] = item
        return cls(ante=ante, sizes=sizes, conf=conf, lift=lift,
                   support=support, cons=cons, n_rows=n_rows,
                   n_rules=len(rules), n_items=n_items, version=version)

    # ------------------------------------------------------------------
    # persistence through the checkpoint store (atomic, manifest-driven)
    # ------------------------------------------------------------------
    def save(self, index_dir: str) -> str:
        """Write this index as checkpoint step ``version`` under index_dir."""
        tree = {f: getattr(self, f) for f in _ARRAY_FIELDS}
        extra = {"kind": "rule_index", "n_rows": self.n_rows,
                 "n_rules": self.n_rules, "n_items": self.n_items,
                 "version": self.version}
        return ckpt_store.save(index_dir, self.version, tree, extra=extra)

    @classmethod
    def load(cls, index_dir: str,
             version: Optional[int] = None) -> "RuleIndex":
        if version is None:
            version = ckpt_store.latest_step(index_dir)
            if version is None:
                raise FileNotFoundError(f"no rule index under {index_dir}")
        step_dir = os.path.join(index_dir, f"step_{version:09d}")
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
        extra = manifest["extra"]
        if extra.get("kind") != "rule_index":
            raise ValueError(f"{step_dir} is not a rule index checkpoint")
        like = {key: np.zeros(meta["shape"], dtype=meta["dtype"])
                for key, meta in manifest["arrays"].items()}
        tree, extra = ckpt_store.restore(index_dir, like, step=version)
        arrays = {f: np.asarray(tree[f]) for f in _ARRAY_FIELDS}
        return cls(**arrays, n_rows=extra["n_rows"], n_rules=extra["n_rules"],
                   n_items=extra["n_items"], version=extra["version"])

    # ------------------------------------------------------------------
    def same_arrays(self, other: "RuleIndex") -> bool:
        """Byte-identical array payloads (determinism / round-trip checks)."""
        return all(np.array_equal(getattr(self, f), getattr(other, f))
                   for f in _ARRAY_FIELDS)
