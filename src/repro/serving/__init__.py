"""Online rule-serving plane: compiled rule index + batched recommendation
engine (the query-side twin of ``repro.pipeline``).

Two ways to drive it, one loop underneath:

* closed-loop — ``RecommendationEngine.serve(queries)`` replays a trace
  (a compat shim over the continuous-batching loop, bit-identical to the
  pre-redesign engine);
* open-loop — ``submit(query) -> Handle`` / ``poll`` / ``drain`` on the
  :class:`AsyncServer`: slot-based admission, AOT-warmed bucket ladder,
  SLO-aware shedding, optional background drain thread.
"""
from repro.serving.admission import (BucketLadder, Handle, Query,
                                     RequestQueue, ShedError, SloGovernor,
                                     VirtualClock, WallClock)
from repro.serving.cache import ResultCache, basket_key
from repro.serving.engine import (QueryLike, RecommendationEngine,
                                  ServingConfig, ServingReport)
from repro.serving.index import RuleIndex
from repro.serving.oracle import recommend_bruteforce
from repro.serving.server import AsyncServer, AsyncServingReport

__all__ = [
    "AsyncServer", "AsyncServingReport", "BucketLadder", "Handle", "Query",
    "QueryLike", "RecommendationEngine", "RequestQueue", "ResultCache",
    "RuleIndex", "ServingConfig", "ServingReport", "ShedError",
    "SloGovernor", "VirtualClock", "WallClock", "basket_key",
    "recommend_bruteforce",
]
