"""Online rule-serving plane: compiled rule index + batched recommendation
engine (the query-side twin of ``repro.pipeline``)."""
from repro.serving.cache import ResultCache, basket_key
from repro.serving.engine import (RecommendationEngine, ServingConfig,
                                  ServingReport)
from repro.serving.index import RuleIndex
from repro.serving.oracle import recommend_bruteforce

__all__ = [
    "RecommendationEngine", "ResultCache", "RuleIndex", "ServingConfig",
    "ServingReport", "basket_key", "recommend_bruteforce",
]
