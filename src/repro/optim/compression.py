"""Gradient compression for cross-pod links.

Two composable transforms (used by the shard_map DP train step in
``repro.launch.train`` when ``--compress`` is on, and unit-tested for
convergence):

* **top-k sparsification with error feedback** — keep the k largest-|g|
  entries per tensor, accumulate the residual locally and add it back next
  step (Stich et al.); the all-reduce then moves k values + k indices
  instead of the dense tensor.
* **int8 stochastic-free linear quantization** — per-tensor absmax scale;
  psum runs on int32 accumulators (values fit: 8-bit × ≤2¹⁵ ranks).

Both are exact-shape pytree transforms so they compose with any optimizer.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# top-k + error feedback
# ---------------------------------------------------------------------------

def topk_sparsify(g: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    """Zero all but the ⌈k_frac·n⌉ largest-magnitude entries (dense carrier:
    the sparsity is what the wire format would exploit; semantics only)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * k_frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0).astype(g.dtype)


def ef_compress(grads: Any, errors: Any, k_frac: float) -> Tuple[Any, Any]:
    """(grads, error-carry) -> (compressed grads, new error-carry)."""
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        comp = topk_sparsify(acc, k_frac)
        return comp.astype(g.dtype), acc - comp

    pairs = jax.tree.map(one, grads, errors)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, err


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# int8 quantized all-reduce
# ---------------------------------------------------------------------------

def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def psum_int8(g: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Quantized all-reduce: a SHARED scale is agreed first (pmax of
    per-rank absmax — one scalar all-reduce), then int8 payloads are summed
    in int32 and dequantized once.  Error ≤ 0.5·scale per rank."""
    s_shared = jax.lax.pmax(
        jnp.max(jnp.abs(g.astype(jnp.float32))), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / s_shared),
                 -127, 127).astype(jnp.int8)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return q_sum.astype(jnp.float32) * s_shared


def compression_ratio(k_frac: float, bits: int = 32) -> float:
    """Wire-bytes ratio for top-k (value+index) vs dense f32."""
    return k_frac * (bits + 32) / 32.0
