"""AdamW + cosine schedule + global-norm clipping, pure pytree (no optax).

Optimizer state is f32 regardless of param dtype (mixed-precision master
moments); the sharding layer (distributed/meshes.py) additionally shards the
moments over the ``data`` axis (ZeRO-1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState
                 ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, n):
        mhat = m / bc1
        nhat = n / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(mu, nu, step), {"grad_norm": gnorm, "lr": lr}
