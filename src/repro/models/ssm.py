"""Mamba-style selective SSM branch (Hymba's parallel-head partner).

Selective scan: h_t = exp(Δ_t·A)⊙h_{t-1} + Δ_t·B_t·x_t ; y_t = C_t·h_t + D·x_t
realized as a ``lax.scan`` over time (correctness path) with per-step state
carry for decode.  Channel dimension is head-sharded on the ``model`` axis
(state is per-channel — no cross-device traffic inside the scan).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init


def ssm_init(key, cfg, dtype) -> Params:
    sc = cfg.ssm
    d = cfg.d_model
    di = sc.expand * d
    ks = jax.random.split(key, 7)
    dt_rank = max(16, d // 16)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (sc.d_conv, di), jnp.float32) * 0.2).astype(dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * sc.d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, sc.d_state + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _conv1d_causal(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: [B,S,di]; w: [K,di]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out


def _selective_scan(u, dt, A, B, C, D, h0=None, impl: str = "scan"):
    """u,dt: [B,S,di]; A: [di,N]; B,C: [B,S,N].  Returns y [B,S,di], h_last.

    impl="associative": h_t = a_t⊙h_{t-1} + b_t via log-depth
    ``lax.associative_scan`` — replaces S sequential state updates with
    log₂S vectorized passes (the production full-sequence path)."""
    Bsz, S, di = u.shape
    N = A.shape[1]
    dA = jnp.exp(dt[..., None] * A[None, None])             # [B,S,di,N]
    dBu = dt[..., None] * B[:, :, None, :] * u[..., None]   # [B,S,di,N]

    if h0 is None:
        h0 = jnp.zeros((Bsz, di, N), jnp.float32)

    if impl == "associative":
        a = dA.astype(jnp.float32)
        b = dBu.astype(jnp.float32)
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", h, C.astype(jnp.float32))
        y = y + D[None, None] * u.astype(jnp.float32)
        return y, h[:, -1]

    if impl == "chunked":
        # sequential over S/c chunks (state carry), associative within a
        # chunk: log₂c passes touch only the [B,c,di,N] chunk instead of
        # log₂S passes over the full sequence — HBM traffic drops ~S/c-fold
        # on the inter-pass reads (§Perf hillclimb A).
        c = 256
        if S % c != 0:
            return _selective_scan(u, dt, A, B, C, D, h0, impl="associative")
        G = S // c
        a_all = dA.astype(jnp.float32).reshape(Bsz, G, c, di, N)
        b_all = dBu.astype(jnp.float32).reshape(Bsz, G, c, di, N)
        C_all = C.astype(jnp.float32).reshape(Bsz, G, c, N)

        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, a2 * b1 + b2

        def chunk_step(h, xs):
            a_c, b_c, C_c = xs                       # [B,c,di,N], [B,c,N]
            b_c = b_c.at[:, 0].add(a_c[:, 0] * h)
            _, hs = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
            y_c = jnp.einsum("bsdn,bsn->bsd", hs, C_c)
            return hs[:, -1], y_c

        h_last, ys = jax.lax.scan(
            chunk_step, h0,
            (a_all.swapaxes(0, 1), b_all.swapaxes(0, 1), C_all.swapaxes(0, 1)))
        y = ys.swapaxes(0, 1).reshape(Bsz, S, di)
        y = y + D[None, None] * u.astype(jnp.float32)
        return y, h_last

    def step(h, xs):
        dA_t, dBu_t, C_t = xs
        h = dA_t * h + dBu_t                                # [B,di,N]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (dA.swapaxes(0, 1).astype(jnp.float32),
          dBu.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32))
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + D[None, None] * u.astype(jnp.float32)
    return y, h_last


def ssm_forward(p: Params, cfg, x: jnp.ndarray,
                state: Dict | None = None) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence (train/prefill).  Returns (y, final_state)."""
    sc = cfg.ssm
    B, S, d = x.shape
    di = sc.expand * d
    dt_rank = p["dt_proj"].shape[0]
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)
    conv_in = u
    if state is not None:
        conv_in = jnp.concatenate([state["conv"], u], axis=1)
        u_c = _conv1d_causal(conv_in, p["conv_w"])[:, -S:]
    else:
        u_c = _conv1d_causal(u, p["conv_w"])
    u_c = jax.nn.silu(u_c)
    proj = u_c @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + sc.d_state], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h0 = state["h"] if state is not None else None
    impl = cfg.ssm_impl if S > 1 else "scan"
    y, h_last = _selective_scan(u_c, dt, A, Bc, Cc, p["D"], h0, impl=impl)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_state = {
        "h": h_last,
        "conv": (conv_in if state is not None else u)[:, -(sc.d_conv - 1):, :],
    }
    return y @ p["out_proj"], new_state


def ssm_init_state(cfg, batch: int, dtype) -> Dict:
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, sc.d_state), jnp.float32),
        "conv": jnp.zeros((batch, sc.d_conv - 1, di), dtype),
    }
