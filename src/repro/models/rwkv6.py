"""RWKV-6 "Finch" — attention-free time-mix with data-dependent decay.

Time-mix (per head, head_dim n): S_t = diag(w_t)·S_{t-1} + k_tᵀ·v_t,
y_t = r_t·(S_{t-1} + diag(u)·k_tᵀ·v_t), with per-token decay
w_t = exp(-exp(ŵ_t)) produced by a LoRA on the shifted input (the paper's
data-dependent decay).  Full sequence = ``lax.scan`` over time; decode carries
(S, last-x) state.  The chunked Pallas kernel in ``repro.kernels.rwkv6_wkv``
implements the same recurrence blockwise for TPU.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init

LORA_DIM = 96
MIX_LORA = 32


def rwkv_time_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 12)
    return {
        "mu_base": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(jnp.float32),
        "mix_w1": dense_init(ks[1], d, 5 * MIX_LORA, dtype),
        "mix_w2": (jax.random.normal(ks[2], (5, MIX_LORA, d), jnp.float32) * 0.01).astype(dtype),
        "w_base": jnp.zeros((d,), jnp.float32) - 6.0,
        "w_lora1": dense_init(ks[3], d, LORA_DIM, dtype),
        "w_lora2": (jax.random.normal(ks[4], (LORA_DIM, d), jnp.float32) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[5], (H, cfg.head_dim), jnp.float32) * 0.5),
        "wr": dense_init(ks[6], d, d, dtype),
        "wk": dense_init(ks[7], d, d, dtype),
        "wv": dense_init(ks[8], d, d, dtype),
        "wg": dense_init(ks[9], d, d, dtype),
        "wo": dense_init(ks[10], d, d, dtype),
        "ln_scale": jnp.ones((d,), jnp.float32),
    }


def rwkv_channel_init(key, cfg, dtype) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": dense_init(ks[0], d, ff, dtype),
        "wv": dense_init(ks[1], ff, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
    }


def _token_shift(x: jnp.ndarray, last: jnp.ndarray | None) -> jnp.ndarray:
    """x: [B,S,d] -> previous-token tensor (zeros/carry at t=0)."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if last is not None:
        prev = prev.at[:, 0].set(last)
    return prev


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: [B,S,H,n]; u: [H,n]; s0: [B,H,n,n] -> y [B,S,H,n], s_last."""
    def step(s, xs):
        r_t, k_t, v_t, w_t = xs                            # [B,H,n]
        kv = k_t[..., :, None] * v_t[..., None, :]         # [B,H,n,n]
        y = jnp.einsum("bhn,bhnm->bhm", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), s_last


_LOG_CLAMP = 40.0


def _wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """Chunked WKV (same math as kernels/rwkv6_wkv, pure jnp): T/c grid
    steps of dense [c,·] matrix work instead of T sequential state updates.
    Exponents are clamped at ±40 (contributions through decay < e⁻⁴⁰ are
    zero to f32 anyway).  Falls back to the sequential scan when T % c."""
    B, T, H, n = r.shape
    c = min(chunk, T)
    if T % c != 0:
        return _wkv_scan(r, k, v, w, u, s0)
    G = T // c

    def resh(x):
        return x.reshape(B, G, c, H, n).swapaxes(0, 1).astype(jnp.float32)

    rs, ks, vs, ws = map(resh, (r, k, v, w))

    def chunk_step(S, xs):
        rc, kc, vc, wc = xs                               # [B,c,H,n]
        lw = jnp.log(wc)
        logP = jnp.cumsum(lw, axis=1)                     # inclusive
        logPm1 = logP - lw
        r_hat = rc * jnp.exp(logPm1)                      # decay-adjusted r
        k_hat = kc * jnp.exp(jnp.minimum(-logP, _LOG_CLAMP))
        y_state = jnp.einsum("bchn,bhnm->bchm", r_hat, S)
        A = jnp.einsum("bthn,bshn->bhts", r_hat, k_hat)   # [B,H,c,c]
        ti = jnp.arange(c)[:, None]
        si = jnp.arange(c)[None, :]
        A = jnp.where((si < ti)[None, None], A, 0.0)
        y_intra = jnp.einsum("bhts,bshn->bthn", A, vc)
        diag = jnp.einsum("bchn,hn,bchn->bch", rc, u.astype(jnp.float32), kc)
        y = y_state + y_intra + diag[..., None] * vc
        decay_all = jnp.exp(logP[:, -1])                  # [B,H,n]
        k2 = kc * jnp.exp(logP[:, -1:, :, :] - logP)
        S_new = decay_all[..., None] * S + jnp.einsum("bchn,bchm->bhnm", k2, vc)
        return S_new, y

    s_last, ys = jax.lax.scan(chunk_step, s0.astype(jnp.float32),
                              (rs, ks, vs, ws))
    y = ys.swapaxes(0, 1).reshape(B, T, H, n)
    return y, s_last


def _ddlerp(p: Params, x, prev):
    """Data-dependent token-shift interpolation -> per-stream mixed inputs."""
    xx = prev - x
    base = x + xx * p["mu_base"][0][None, None].astype(x.dtype)   # shared pre-mix
    lora = jnp.tanh(base @ p["mix_w1"])                    # [B,S,5*MIX]
    B, S, _ = x.shape
    lora = lora.reshape(B, S, 5, MIX_LORA)
    delta = jnp.einsum("bsfm,fmd->bsfd", lora, p["mix_w2"]).astype(x.dtype)
    mixed = x[:, :, None, :] + xx[:, :, None, :] * (
        p["mu_base"].astype(x.dtype)[None, None] + delta)
    return [mixed[:, :, i] for i in range(5)]              # w,k,v,r,g streams


def rwkv_time_forward(p: Params, cfg, x: jnp.ndarray,
                      state: Dict | None = None) -> Tuple[jnp.ndarray, Dict]:
    B, S, d = x.shape
    H, n = cfg.n_heads, cfg.head_dim
    prev = _token_shift(x, state["tm_x"] if state is not None else None)
    xw, xk, xv, xr, xg = _ddlerp(p, x, prev)
    w_hat = p["w_base"] + (jnp.tanh(xw @ p["w_lora1"]) @ p["w_lora2"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_hat))                           # [B,S,d] in (0,1)
    r = (xr @ p["wr"]).reshape(B, S, H, n)
    k = (xk @ p["wk"]).reshape(B, S, H, n)
    v = (xv @ p["wv"]).reshape(B, S, H, n)
    g = jax.nn.silu(xg @ p["wg"])
    s0 = state["wkv"] if state is not None else jnp.zeros((B, H, n, n), jnp.float32)
    if cfg.time_mix_impl == "chunked" and S > 1:
        y, s_last = _wkv_chunked(r, k, v, w.reshape(B, S, H, n), p["u"], s0,
                                 cfg.rwkv_chunk)
    else:
        y, s_last = _wkv_scan(r, k, v, w.reshape(B, S, H, n), p["u"], s0)
    # group-norm per head
    y = y.reshape(B, S, H, n)
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = (y.reshape(B, S, d) * p["ln_scale"]).astype(x.dtype) * g
    out = y @ p["wo"]
    new_state = {"tm_x": x[:, -1], "wkv": s_last}
    return out, new_state


def rwkv_channel_forward(p: Params, cfg, x: jnp.ndarray,
                         state: Dict | None = None) -> Tuple[jnp.ndarray, Dict]:
    prev = _token_shift(x, state["cm_x"] if state is not None else None)
    xx = prev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, {"cm_x": x[:, -1]}


def rwkv_init_state(cfg, batch: int, dtype) -> Dict:
    H, n = cfg.n_heads, cfg.head_dim
    return {
        "tm_x": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_x": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, n, n), jnp.float32),
    }
