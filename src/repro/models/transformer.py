"""Decoder assembly: init / train-loss / prefill / decode for all 10 archs.

Layers are *stacked* (leading axis = layer) and the body is a single
``lax.scan`` step — HLO size is O(1) in depth, which keeps 60-layer 236B
configs compilable and is remat-friendly.  Block types:

* ``attn``   — [pre-norm GQA|MLA] + [pre-norm SwiGLU | MoE]
* ``rwkv``   — [pre-norm RWKV6 time-mix] + [pre-norm channel-mix]
* ``hybrid`` — parallel attention + Mamba heads, fused by per-branch norms
               (Hymba), then SwiGLU.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv6, ssm as ssm_mod, stubs
from repro.models.layers import (Params, chunked_softmax_xent, dtype_of,
                                 embed_init, mlp, mlp_init, rmsnorm,
                                 rmsnorm_init, sequence_shard, softmax_xent)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(cfg: ModelConfig, key, moe_layer: bool) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"ln1": rmsnorm_init(d, jnp.float32), "ln2": rmsnorm_init(d, jnp.float32)}
    if cfg.block_type in ("attn", "hybrid"):
        if cfg.mla is not None:
            p["attn"] = attn.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attn.gqa_init(ks[0], cfg, dtype)
    if cfg.block_type == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg, dtype)
        p["fuse_ln_a"] = rmsnorm_init(d, jnp.float32)
        p["fuse_ln_s"] = rmsnorm_init(d, jnp.float32)
    if cfg.block_type == "rwkv":
        p["time"] = rwkv6.rwkv_time_init(ks[0], cfg, dtype)
        p["channel"] = rwkv6.rwkv_channel_init(ks[1], cfg, dtype)
    elif moe_layer:
        p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
    else:
        p["ffn"] = mlp_init(ks[2], d, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_head, k_layers, k_stub, k_dense = jax.random.split(key, 5)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense
    moe_layer = cfg.moe is not None and cfg.moe.n_experts > 0

    layer_keys = jax.random.split(k_layers, n_scan)
    layers = jax.vmap(lambda k: _layer_init(cfg, k, moe_layer))(layer_keys)

    p: Params = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_ln": rmsnorm_init(cfg.d_model, jnp.float32),
        "layers": layers,
    }
    if not cfg.tie_embeddings and cfg.frontend != "audio":
        p["lm_head"] = embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype)
    if n_dense:
        dense_keys = jax.random.split(k_dense, n_dense)
        p["dense_layers"] = [
            _layer_init(cfg, dense_keys[i], moe_layer=False) for i in range(n_dense)]
    if cfg.frontend == "audio":
        p["audio"] = stubs.audio_head_init(k_stub, cfg, dtype)
    if cfg.frontend == "vision":
        p["vision"] = stubs.vision_proj_init(k_stub, cfg, dtype)
    return p


def param_count(params: Params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


# ---------------------------------------------------------------------------
# layer forward (full sequence, no cache)
# ---------------------------------------------------------------------------


def _block_full(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                window) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One layer, full sequence.  Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.block_type == "rwkv":
        y, _ = rwkv6.rwkv_time_forward(p["time"], cfg, rmsnorm(p["ln1"], x, cfg.rms_eps))
        x = x + y
        y, _ = rwkv6.rwkv_channel_forward(p["channel"], cfg, rmsnorm(p["ln2"], x, cfg.rms_eps))
        return x + y, aux
    h = rmsnorm(p["ln1"], x, cfg.rms_eps)
    if cfg.block_type == "hybrid":
        a = attn.gqa_forward(p["attn"], cfg, h, window)
        s, _ = ssm_mod.ssm_forward(p["ssm"], cfg, h)
        fused = 0.5 * (rmsnorm(p["fuse_ln_a"], a, cfg.rms_eps)
                       + rmsnorm(p["fuse_ln_s"], s, cfg.rms_eps))
        x = x + fused
    else:
        if cfg.mla is not None:
            x = x + attn.mla_forward(p["attn"], cfg, h, window)
        else:
            x = x + attn.gqa_forward(p["attn"], cfg, h, window)
    h2 = rmsnorm(p["ln2"], x, cfg.rms_eps)
    if "moe" in p:
        y, aux = moe_mod.moe_forward(p["moe"], cfg, h2)
    else:
        y = mlp(p["ffn"], h2)
    out = x + y
    if cfg.remat_policy == "names":
        out = checkpoint_name(out, "block_out")
    return out, aux


def forward_hidden(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Embeddings -> final hidden states.  x: [B, S, d]."""
    windows = attn.layer_windows(cfg)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    for i in range(n_dense):
        x, _ = _block_full(cfg, params["dense_layers"][i], x, windows[i])

    def body(carry, xs):
        h, aux = carry
        layer_p, w = xs
        if cfg.sequence_parallel:
            h = sequence_shard(h)
        h, a = _block_full(cfg, layer_p, h, w)
        if cfg.sequence_parallel:
            h = sequence_shard(h)
        return (h, aux + a), None

    if cfg.remat_policy == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif cfg.remat_policy == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    elif cfg.remat_policy == "names":
        # save the post-collective residual stream: backward recompute then
        # skips re-running the TP all-reduces (collective-bound cells trade
        # ~2 [B,S,d] saves per layer for ~1/3 of the AR volume — §Perf C)
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names(
                "block_out"), prevent_cse=False)

    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (params["layers"], windows[n_dense:]))
    else:
        aux = jnp.float32(0.0)
        L = cfg.n_layers - n_dense
        for i in range(L):
            layer_p = jax.tree.map(lambda a: a[i], params["layers"])
            (x, aux), _ = body((x, aux), (layer_p, windows[n_dense + i]))
    return rmsnorm(params["final_ln"], x, cfg.rms_eps), aux


# ---------------------------------------------------------------------------
# embedding / unembedding per modality
# ---------------------------------------------------------------------------


def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    if cfg.frontend == "audio":
        return batch["frames"].astype(dtype_of(cfg.activ_dtype))
    x = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision":
        x = stubs.vision_prepend(params["vision"], batch["vision_embeds"].astype(x.dtype), x)
    return x


def _unembed_matrix(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def model_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Mean next-token cross-entropy (+ MoE aux)."""
    x = embed_inputs(params, cfg, batch)
    h, aux = forward_hidden(params, cfg, x)

    if cfg.frontend == "audio":
        logits = stubs.audio_logits(params["audio"], h[:, :-1])
        loss = softmax_xent(logits, batch["labels"][:, 1:])
        return loss + aux

    if cfg.frontend == "vision":
        nv = cfg.n_vision_tokens
        h_pred = h[:, nv - 1:-1]
        labels = batch["tokens"]
    else:
        h_pred = h[:, :-1]
        labels = batch["tokens"][:, 1:]

    w = _unembed_matrix(params, cfg)
    B, S, d = h_pred.shape
    if cfg.vocab_loss_chunk:
        loss = chunked_softmax_xent(
            h_pred.reshape(B * S, d), w, labels.reshape(B * S), cfg.vocab_loss_chunk)
    else:
        logits = jnp.einsum("bsd,vd->bsv", h_pred, w)
        loss = softmax_xent(logits, labels)
    return loss + aux


# ---------------------------------------------------------------------------
# KV-cache / recurrent-state decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    """Stacked [L, ...] cache pytree."""
    dtype = dtype_of(cfg.activ_dtype)
    L = cfg.n_layers
    if cfg.block_type == "rwkv":
        st = rwkv6.rwkv_init_state(cfg, batch, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), st)
    cache: Dict[str, jnp.ndarray] = {}
    if cfg.mla is not None:
        m = cfg.mla
        cache["c_kv"] = jnp.zeros((L, batch, max_seq, m.kv_lora_rank), dtype)
        cache["k_rope"] = jnp.zeros((L, batch, max_seq, m.qk_rope_head_dim), dtype)
    else:
        cache["k"] = jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype)
        cache["v"] = jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype)
    if cfg.block_type == "hybrid":
        st = ssm_mod.ssm_init_state(cfg, batch, dtype)
        cache["h"] = jnp.broadcast_to(st["h"], (L,) + st["h"].shape)
        cache["conv"] = jnp.broadcast_to(st["conv"], (L,) + st["conv"].shape)
    return cache


def _block_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray, cache: Dict,
                  pos, window) -> Tuple[jnp.ndarray, Dict]:
    """One layer, one token.  cache: this layer's slice."""
    new_cache = dict(cache)
    if cfg.block_type == "rwkv":
        st = {"tm_x": cache["tm_x"], "wkv": cache["wkv"], "cm_x": cache["cm_x"]}
        y, st_t = rwkv6.rwkv_time_forward(p["time"], cfg, rmsnorm(p["ln1"], x, cfg.rms_eps), st)
        x = x + y
        y, st_c = rwkv6.rwkv_channel_forward(p["channel"], cfg, rmsnorm(p["ln2"], x, cfg.rms_eps), st)
        x = x + y
        new_cache.update(tm_x=st_t["tm_x"], wkv=st_t["wkv"], cm_x=st_c["cm_x"])
        return x, new_cache
    h = rmsnorm(p["ln1"], x, cfg.rms_eps)
    if cfg.block_type == "hybrid":
        a, kv = attn.gqa_decode(p["attn"], cfg, h, {"k": cache["k"], "v": cache["v"]}, pos, window)
        st = {"h": cache["h"], "conv": cache["conv"]}
        s, st2 = ssm_mod.ssm_forward(p["ssm"], cfg, h, st)
        fused = 0.5 * (rmsnorm(p["fuse_ln_a"], a, cfg.rms_eps)
                       + rmsnorm(p["fuse_ln_s"], s, cfg.rms_eps))
        x = x + fused
        new_cache.update(k=kv["k"], v=kv["v"], h=st2["h"], conv=st2["conv"])
    elif cfg.mla is not None:
        y, kv = attn.mla_decode(p["attn"], cfg, h, {"c_kv": cache["c_kv"], "k_rope": cache["k_rope"]}, pos)
        x = x + y
        new_cache.update(c_kv=kv["c_kv"], k_rope=kv["k_rope"])
    else:
        y, kv = attn.gqa_decode(p["attn"], cfg, h, {"k": cache["k"], "v": cache["v"]}, pos, window)
        x = x + y
        new_cache.update(k=kv["k"], v=kv["v"])
    h2 = rmsnorm(p["ln2"], x, cfg.rms_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_forward(p["moe"], cfg, h2)
    else:
        y = mlp(p["ffn"], h2)
    return x + y, new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, pos) -> Tuple[jnp.ndarray, Params]:
    """One decoding step.

    tokens: [B, 1] int32 (or [B, 1, n_codebooks] for audio).
    cache:  stacked [L, ...] pytree.  pos: scalar int32 (current position).
    Returns (logits [B, V] or [B, K, V], new cache).
    """
    if cfg.frontend == "audio":
        x = stubs.audio_embed_tokens(params["audio"], tokens)
    else:
        x = params["embed"][tokens]
    x = x.astype(dtype_of(cfg.activ_dtype))

    windows = attn.layer_windows(cfg)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0

    if n_dense:
        head = {k: jax.tree.map(lambda a: a[:n_dense], v) for k, v in cache.items()}
        tail = {k: jax.tree.map(lambda a: a[n_dense:], v) for k, v in cache.items()}
        for i in range(n_dense):
            sl = jax.tree.map(lambda a: a[i], head)
            x, sl = _block_decode(cfg, params["dense_layers"][i], x, sl, pos, windows[i])
            head = jax.tree.map(lambda buf, s: buf.at[i].set(s), head, sl)
    else:
        tail = cache

    def body(carry, xs):
        h = carry
        layer_p, layer_cache, w = xs
        h, new_c = _block_decode(cfg, layer_p, h, layer_cache, pos, w)
        return h, new_c

    x, new_tail = jax.lax.scan(body, x, (params["layers"], tail, windows[n_dense:]))
    new_cache = new_tail
    if n_dense:
        new_cache = jax.tree.map(lambda hh, tt: jnp.concatenate([hh, tt], 0), head, new_tail)

    h = rmsnorm(params["final_ln"], x, cfg.rms_eps)
    if cfg.frontend == "audio":
        logits = stubs.audio_logits(params["audio"], h)[:, 0]
        return logits, new_cache
    w = _unembed_matrix(params, cfg)
    logits = jnp.einsum("bsd,vd->bsv", h, w)[:, 0]
    return logits, new_cache


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward returning last-position logits (cache is
    rebuilt by the serving layer via decode over saved KV; for the dry-run
    the lowered artifact of interest is the forward itself)."""
    x = embed_inputs(params, cfg, batch)
    h, _ = forward_hidden(params, cfg, x)
    if cfg.frontend == "audio":
        return stubs.audio_logits(params["audio"], h[:, -1:])[:, 0], h
    w = _unembed_matrix(params, cfg)
    return jnp.einsum("bd,vd->bv", h[:, -1], w), h
