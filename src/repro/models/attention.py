"""Attention variants: GQA, sliding-window local/global, MLA (DeepSeek-V2).

Two full-sequence implementations:

* ``naive``   — materializes the [B, H, S, S] score tensor (XLA baseline).
* ``chunked`` — online-softmax over KV chunks inside a ``lax.scan``: peak
  activation memory drops from O(S²) to O(S·chunk).  This is the pure-JAX
  realization of flash attention (the Pallas kernel in
  ``repro.kernels.flash_attention`` is the TPU-native version of the same
  schedule; lowering here stays backend-portable for the dry-run).

Window semantics: ``window <= 0`` means full causal; ``window = w`` allows
key j for query i iff ``i - w < j <= i``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA projections
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, H * hd, dtype),
        "wk": dense_init(k2, d, KV * hd, dtype),
        "wv": dense_init(k3, d, KV * hd, dtype),
        "wo": dense_init(k4, H * hd, d, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal: bool, window: jnp.ndarray | int,
                    q_offset: int | jnp.ndarray = 0) -> jnp.ndarray:
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd].  Returns [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kj <= qi
    w = jnp.asarray(window)
    mask &= jnp.where(w > 0, kj > qi - w, True)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def chunked_attention(q, k, v, *, causal: bool, window: jnp.ndarray | int,
                      chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention, O(S·chunk) memory.  Shapes as naive."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    hd_v = v.shape[-1]          # may differ from q/k head_dim (MLA)
    if S % chunk != 0:
        return naive_attention(q, k, v, causal=causal, window=window)
    n_rep = H // KV
    kc = k.reshape(B, S // chunk, chunk, KV, hd)
    vc = v.reshape(B, S // chunk, chunk, KV, hd_v)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qi = jnp.arange(S)[:, None]
    w = jnp.asarray(window)

    # NOTE the jax.checkpoint: without it, scan-autodiff saves every chunk's
    # [B,H,S,chunk] probability tensor — the full S² matrix in f32, i.e. the
    # exact memory wall flash attention exists to avoid.  With it, backward
    # recomputes p per chunk (flash-backward semantics, found via the
    # buffer-assignment dump; see EXPERIMENTS.md §Perf).
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, i = xs
        k_i = _repeat_kv(k_i, n_rep)
        v_i = _repeat_kv(v_i, n_rep)
        kj = i * chunk + jnp.arange(chunk)[None, :]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_i).astype(jnp.float32) * scale
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= kj <= qi
        mask &= jnp.where(w > 0, kj > qi - w, True)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), v_i).astype(jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((B, H, S), NEG_INF, jnp.float32),
            jnp.zeros((B, H, S), jnp.float32),
            jnp.zeros((B, H, S, hd_v), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(S // chunk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)        # [B,S,H,hd]


def attention_full(q, k, v, cfg, window) -> jnp.ndarray:
    if cfg.attention_impl == "chunked":
        return chunked_attention(q, k, v, causal=True, window=window,
                                 chunk=cfg.attention_chunk)
    return naive_attention(q, k, v, causal=True, window=window)


# ---------------------------------------------------------------------------
# GQA block: full-sequence and decode
# ---------------------------------------------------------------------------

def gqa_forward(p: Params, cfg, x: jnp.ndarray, window, positions=None) -> jnp.ndarray:
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], KV, hd)
    v = _split_heads(x @ p["wv"], KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.sequence_parallel:
        # keep q's SEQUENCE dim sharded on "model" through the attention
        # math (ring-attention-lite: kv replicated, scores [B,H,S/16,S]).
        # Essential when n_heads doesn't divide the TP degree (hymba's 25
        # heads): head-sharding degenerates to replication, but S always
        # divides (§Perf hillclimb A iteration 2).
        from repro.models.layers import sequence_shard
        q = sequence_shard(q)
    out = attention_full(q, k, v, cfg, window)
    return out.reshape(B, S, H * hd) @ p["wo"]


def gqa_prefill(p: Params, cfg, x: jnp.ndarray, window) -> Tuple[jnp.ndarray, Dict]:
    """Forward + return KV for the cache."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.arange(S)[None, :]
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], KV, hd)
    v = _split_heads(x @ p["wv"], KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = attention_full(q, k, v, cfg, window)
    return out.reshape(B, S, H * hd) @ p["wo"], {"k": k, "v": v}


def gqa_decode(p: Params, cfg, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
               window) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode.  x: [B,1,d]; cache k/v: [B,Smax,KV,hd]; pos: scalar."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Smax = cache["k"].shape[1]
    posv = jnp.full((B, 1), pos)
    q = apply_rope(_split_heads(x @ p["wq"], H, hd), posv, cfg.rope_theta)
    k_new = apply_rope(_split_heads(x @ p["wk"], KV, hd), posv, cfg.rope_theta)
    v_new = _split_heads(x @ p["wv"], KV, hd)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    kr = _repeat_kv(k, H // KV)
    vr = _repeat_kv(v, H // KV)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
    kj = jnp.arange(Smax)[None, None, None, :]
    mask = kj <= pos
    w = jnp.asarray(window)
    mask &= jnp.where(w > 0, kj > pos - w, True)
    s = jnp.where(mask, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", prob, vr).reshape(B, 1, H * hd)
    return out @ p["wo"], {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype) -> Params:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank, H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks[4], H * m.v_head_dim, d, dtype),
    }


def _mla_qkv(p, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = (x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,rope]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p: Params, cfg, x: jnp.ndarray, window=0, positions=None,
                return_cache: bool = False):
    """Full-sequence MLA (train/prefill, non-absorbed form)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))], axis=-1)
    # pad v's head_dim to match q/k for the shared kernel? no — direct einsum:
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    if cfg.attention_impl == "chunked" and S % cfg.attention_chunk == 0:
        out = chunked_attention(q, k, v, causal=True, window=0, chunk=cfg.attention_chunk)
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(S)[None, :]
        s = jnp.where((kj <= qi)[None, None], s, NEG_INF)
        prob = jax.nn.softmax(s, -1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", prob, v)
    y = out.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    if return_cache:
        return y, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    return y


def mla_decode(p: Params, cfg, x: jnp.ndarray, cache: Dict, pos) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-form MLA decode: attends in the compressed latent space.

    cache: c_kv [B,Smax,kv_lora], k_rope [B,Smax,rope].  Per-token compute is
    O(Smax · kv_lora) HBM reads — the paper-faithful KV-compression win.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    Smax = cache["c_kv"].shape[1]
    posv = jnp.full((B, 1), pos)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, cfg, x, posv)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new[:, :, 0, :].astype(cache["k_rope"].dtype), (0, pos, 0))
    # absorb wkv_b's K half into q: q_eff [B,1,H,kv_lora]
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[:, :, :m.qk_nope_head_dim]            # [lora, H, nope]
    w_uv = wkv_b[:, :, m.qk_nope_head_dim:]            # [lora, H, v]
    q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    s = (jnp.einsum("bqhl,bkl->bhqk", q_eff, c_kv)
         + jnp.einsum("bqhr,bkr->bhqk", q_rope, k_rope)).astype(jnp.float32) * scale
    kj = jnp.arange(Smax)[None, None, None, :]
    s = jnp.where(kj <= pos, s, NEG_INF)
    prob = jax.nn.softmax(s, -1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkl->bqhl", prob, c_kv)     # latent context
    out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv)
    y = out.reshape(B, 1, H * m.v_head_dim) @ p["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def layer_windows(cfg) -> jnp.ndarray:
    """Per-layer attention window vector: 0 = global, w = sliding window."""
    if cfg.global_every and cfg.local_window:
        idx = jnp.arange(cfg.n_layers)
        return jnp.where((idx + 1) % cfg.global_every == 0, 0, cfg.local_window)
    return jnp.zeros((cfg.n_layers,), jnp.int32)
