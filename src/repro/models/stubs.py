"""Modality-frontend STUBS (per task spec: the transformer backbone is real,
the frontend supplies precomputed embeddings through ``input_specs()``).

* audio (musicgen-large): EnCodec frame embeddings arrive precomputed as
  [B, S, d_model]; the backbone owns per-codebook unembedding heads and
  (for decode) per-codebook token embeddings that are summed.
* vision (internvl2-26b): ViT patch embeddings arrive precomputed as
  [B, n_vision_tokens, d_model] and are prepended to the text embeddings
  behind a learned projection.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, embed_init


def audio_head_init(key, cfg, dtype) -> Params:
    ks = jax.random.split(key, 2 * cfg.n_codebooks)
    return {
        "codebook_embed": jnp.stack(
            [embed_init(ks[i], cfg.vocab_size, cfg.d_model, dtype)
             for i in range(cfg.n_codebooks)]),
        "codebook_head": jnp.stack(
            [embed_init(ks[cfg.n_codebooks + i], cfg.vocab_size, cfg.d_model, dtype)
             for i in range(cfg.n_codebooks)]),
    }


def audio_embed_tokens(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: [B, S, n_codebooks] -> summed embeddings [B, S, d]."""
    embs = jnp.einsum("ksv->", jnp.zeros((1, 1, 1)))  # placeholder no-op
    del embs
    out = 0.0
    for i in range(p["codebook_embed"].shape[0]):
        out = out + p["codebook_embed"][i][tokens[..., i]]
    return out


def audio_logits(p: Params, h: jnp.ndarray) -> jnp.ndarray:
    """h: [B, S, d] -> [B, S, n_codebooks, V]."""
    return jnp.einsum("bsd,kvd->bskv", h, p["codebook_head"])


def vision_proj_init(key, cfg, dtype) -> Params:
    return {"proj": dense_init(key, cfg.d_model, cfg.d_model, dtype)}


def vision_prepend(p: Params, vis_embeds: jnp.ndarray, txt_embeds: jnp.ndarray) -> jnp.ndarray:
    """vis: [B, Nv, d] (stub frontend output), txt: [B, S, d]."""
    return jnp.concatenate([vis_embeds @ p["proj"], txt_embeds], axis=1)
