"""Shared neural-net building blocks (pure-pytree, no framework deps).

Parameters are nested dicts of jnp arrays.  Initializers take an explicit
PRNG key and return pytrees; apply functions are pure.  All blocks respect
``cfg.param_dtype`` / ``cfg.activ_dtype`` (params bf16, math where it matters
in f32).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def sequence_shard(x: jnp.ndarray) -> jnp.ndarray:
    """Sequence-parallel sharding constraint (Korthikanti et al.): between
    blocks, activations [B, S, d] are sharded on ("pod","data") × batch and
    "model" × sequence, so the per-layer residual saves (and norms /
    elementwise work) are TP-sharded instead of replicated.  GSPMD inserts
    the all-gather before attention and the reduce-scatter after the row
    matmuls.  No-op outside a mesh context or when dims don't divide."""
    from repro.core.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names or x.ndim < 3:
        return x
    names = mesh.axis_names
    batch_ax = tuple(a for a in ("pod", "data") if a in names)
    if "model" not in names or not batch_ax:
        return x
    import numpy as _np
    bsz = int(_np.prod([mesh.shape[a] for a in batch_ax]))
    if x.shape[0] % bsz != 0 or x.shape[1] % mesh.shape["model"] != 0:
        return x
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(
        x, _P(batch_ax, "model", *([None] * (x.ndim - 2))))


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., seq, hd/2]
    angles = angles[..., None, :]                       # [..., seq, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy; logits [..., V] in any float dtype (f32 math)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_softmax_xent(x: jnp.ndarray, embed: jnp.ndarray, labels: jnp.ndarray,
                         chunk: int, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Cross-entropy without materializing [tokens, V] logits.

    Scans over vocab chunks accumulating a running logsumexp and picking the
    label logit on the fly.  x: [T, d] final hidden states, embed: [V, d]
    (the unembedding), labels: [T].  This is the §Perf "chunked vocab loss"
    lever: HBM traffic drops from O(T·V) to O(T·V/..) streamed weights with a
    [T, chunk] working set.
    """
    T, d = x.shape
    V = embed.shape[0]
    assert V % chunk == 0, (V, chunk)
    n = V // chunk
    w = embed.reshape(n, chunk, d)

    # checkpointed: otherwise scan-autodiff saves every [T, chunk] logits
    # tile for backward — re-materializing the full [T, V] matrix the chunked
    # loss exists to avoid (same pattern as chunked attention).
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, wc_i):
        m, s, ll = carry
        wc, i = wc_i
        logits = (x @ wc.T).astype(jnp.float32)            # [T, chunk]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(logits - m_new[:, None]).sum(-1)
        local = labels - i * chunk
        in_chunk = (local >= 0) & (local < chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[:, None], axis=-1)[:, 0]
        ll = jnp.where(in_chunk, picked, ll)
        return (m_new, s, ll), None

    init = (jnp.full((T,), -jnp.inf, jnp.float32),
            jnp.zeros((T,), jnp.float32),
            jnp.zeros((T,), jnp.float32))
    (m, s, ll), _ = jax.lax.scan(body, init, (w, jnp.arange(n)))
    nll = (m + jnp.log(s)) - ll
    if mask is not None:
        maskf = mask.astype(jnp.float32)
        return jnp.sum(nll * maskf) / jnp.maximum(jnp.sum(maskf), 1.0)
    return jnp.mean(nll)
