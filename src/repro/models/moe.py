"""Token-choice top-k Mixture-of-Experts with capacity-factor dispatch.

Design (TPU-native, GSPMD-shardable):

* Tokens stay grouped by batch row (group = sequence): router, ranking and
  dispatch indices are computed per group, so capacity is per-group
  ``C = ceil(S * top_k / E * capacity_factor)`` and all shapes are static.
* Dispatch uses *compact* [E, C] index buffers (gather/scatter-add), not the
  GShard [S, E, C] one-hot einsum — memory falls from O(S·E·C) to O(E·C·d),
  which is what makes 160-expert DeepSeek-V2 lowerable at 32k sequer length.
* Experts are sharded on the ``model`` ("expert") mesh axis; the gather in /
  scatter-out become all-to-alls under GSPMD — the MoE collective term in
  §Roofline.

The MB-scheduler connection (DESIGN.md §2): expert load imbalance is in-chip
heterogeneity; the router aux loss plus capacity factor plays the same role as
proportional shard sizing at the cluster level.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init


def moe_capacity(seq_len: int, n_experts: int, top_k: int, capacity_factor: float) -> int:
    c = math.ceil(seq_len * top_k / n_experts * capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))  # pad for TPU lane alignment


def _expert_shard(x_t: jnp.ndarray) -> jnp.ndarray:
    """Sharding constraint for [E, B, C, d] (expert-major) dispatch tensors:
    E on the expert-parallel axis ("data"), matching the expert-weight
    sharding.  No-op outside a mesh context or when E doesn't divide."""
    from repro.core.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names or "data" not in mesh.axis_names:
        return x_t
    if x_t.shape[0] % mesh.shape["data"] != 0:
        return x_t
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(
        x_t, _P("data", None, None, None))


def moe_init(key, cfg, dtype) -> Params:
    mc = cfg.moe
    d = cfg.d_model
    ff = mc.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    E = mc.n_experts
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) / math.sqrt(ff)).astype(dtype),
    }
    if mc.n_shared:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d, ff * mc.n_shared, dtype)
    return p


def moe_forward(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y, aux_loss).  Group axis = B."""
    mc = cfg.moe
    B, S, d = x.shape
    E, K = mc.n_experts, mc.top_k
    C = moe_capacity(S, E, K, mc.capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"])            # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)           # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (switch-style) ---
    me = probs.mean(axis=(0, 1))                              # [E] mean prob
    one_hot_top1 = jax.nn.one_hot(expert_ids[..., 0], E)
    ce = one_hot_top1.mean(axis=(0, 1))                       # [E] fraction
    aux = E * jnp.sum(me * ce) * mc.router_aux_coef

    # --- rank within expert, per group (vectorized over B) ---
    flat_ids = expert_ids.reshape(B, S * K)                   # slot-major
    flat_gate = gate_vals.reshape(B, S * K)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)     # [B, S*K, E]
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot       # rank before self
    position = jnp.take_along_axis(pos_in_expert, flat_ids[..., None], axis=-1)[..., 0]
    keep = position < C
    token_of_slot = jnp.arange(S * K) // K                    # [S*K]

    # --- compact dispatch buffers ---
    safe_e = jnp.where(keep, flat_ids, 0)
    safe_c = jnp.where(keep, position, C)                     # C = drop bucket

    def build(eids, cpos, weights):
        idx = jnp.zeros((E, C + 1), jnp.int32).at[eids, cpos].set(token_of_slot, mode="drop")
        wbuf = jnp.zeros((E, C + 1), jnp.float32).at[eids, cpos].set(weights, mode="drop")
        return idx[:, :C], wbuf[:, :C]

    idx_buf, w_buf = jax.vmap(build)(safe_e, safe_c, jnp.where(keep, flat_gate, 0.0))

    # --- gather -> expert MLP -> scatter-add ---
    x_e = jax.vmap(lambda xg, ig: xg[ig])(x, idx_buf.reshape(B, E * C))
    x_e = x_e.reshape(B, E, C, d)
    # Token→expert routing as an explicit TRANSPOSE of the two sharded dims,
    # (B@data, E, C, d) -> (E@data, B, C, d): the SPMD partitioner
    # pattern-matches transposed-sharding as one all-to-all, where a bare
    # sharding constraint on the un-transposed layout lowered to
    # all-gather + slice (buffer dump; §Perf hillclimb B).
    x_t = _expert_shard(x_e.swapaxes(0, 1))          # [E@data, B, C, d]

    g = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", x_t, p["w_gate"]))
    u = jnp.einsum("ebcd,edf->ebcf", x_t, p["w_up"])
    y_t = jnp.einsum("ebcf,efd->ebcd", g * u, p["w_down"])
    y_t = _expert_shard(y_t)
    y_e = y_t.swapaxes(0, 1)                         # back to [B@data, E, C, d]
    y_e = y_e * w_buf[..., None].astype(y_e.dtype)

    def combine(ye, ig):
        return jnp.zeros((S, d), ye.dtype).at[ig].add(ye.reshape(E * C, d))

    y = jax.vmap(combine)(y_e, idx_buf.reshape(B, E * C))

    if mc.n_shared:
        from repro.models.layers import mlp
        y = y + mlp(p["shared"], x)
    return y.astype(x.dtype), aux
