"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536.

RWKV-6 "Finch": data-dependent decay WKV recurrence, head_dim 64.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-7b", family="ssm", block_type="rwkv",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab_size=65536,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
    )


register("rwkv6-7b", full, smoke)
