"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.

GQA dense decoder. [hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-3-8b", family="dense", block_type="attn",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab_size=49155, rope_theta=10_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )


register("granite-3-8b", full, smoke)
