"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

128k-context dense decoder, head_dim=128. [hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="mistral-nemo-12b", family="dense", block_type="attn",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
    )


register("mistral-nemo-12b", full, smoke)
