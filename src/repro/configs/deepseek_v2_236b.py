"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 vocab=102400.

MLA (kv_lora=512), 2 shared + 160 routed experts top-6, first layer dense
(d_ff 12288). [arXiv:2405.04434; hf]
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-v2-236b", family="moe", block_type="attn",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12288,                # dense-FFN layers (layer 1)
        vocab_size=102400, rope_theta=10_000.0,
        moe=MoEConfig(n_experts=160, top_k=6, n_shared=2,
                      expert_d_ff=1536, first_dense_layers=1),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1,
                      expert_d_ff=32, first_dense_layers=1),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    )


register("deepseek-v2-236b", full, smoke)
