"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Width/depth-pruned Nemotron-4. [arXiv:2407.14679; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="minitron-8b", family="dense", block_type="attn",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=16384, vocab_size=256000, rope_theta=10_000.0,
        # 256k vocab: chunked vocab loss is the default-on lever here (§Perf)
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
    )


register("minitron-8b", full, smoke)
