"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.

Parallel attention + Mamba heads fused per layer; ssm_state=16; sliding-window
attention on all but 3 global layers. [arXiv:2411.13676; hf]
"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="hymba-1.5b", family="hybrid", block_type="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, vocab_size=32001, rope_theta=10_000.0,
        local_window=1024, global_every=16,  # layers 16, 32 global (+ first handled as local)
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, local_window=16, global_every=2,
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    )


register("hymba-1.5b", full, smoke)
