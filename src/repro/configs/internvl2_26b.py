"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT-6B frontend + InternLM2-20B backbone.  Frontend is a STUB per task
spec: ``input_specs()`` provides precomputed ViT patch embeddings which are
prepended to the token embeddings. [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-26b", family="vlm", block_type="attn",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92553, rope_theta=1_000_000.0,
        frontend="vision", n_vision_tokens=256,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, n_vision_tokens=8,
    )


register("internvl2-26b", full, smoke)
