"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.

16 experts, top-4, fine-grained MoE. [hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="dbrx-132b", family="moe", block_type="attn",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab_size=100352, rope_theta=500_000.0,
        moe=MoEConfig(n_experts=16, top_k=4, expert_d_ff=10752),
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=96),
    )


register("dbrx-132b", full, smoke)
