"""Config system for the `repro` framework.

Every assigned architecture is a :class:`ModelConfig`; the registry maps
``--arch <id>`` to a config factory.  Configs are plain frozen dataclasses so
they hash (usable as jit static args) and print reproducibly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Shape suite assigned to the LM family (see task spec).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Families that may run the long-context decode shape (sub-quadratic path).
LONG_CONTEXT_OK = ("ssm", "hybrid", "swa")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0           # shared (always-on) experts, DeepSeek-style
    expert_d_ff: int = 0        # per-expert hidden size (fine-grained MoE)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading dense-FFN layers (DeepSeek-V2 layer 1)
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention geometry."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2             # d_inner = expand * d_model (mamba branch)
    n_groups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    block_type: str             # attn | rwkv | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False

    # Sliding-window pattern: window size for "local" layers; every
    # `global_every`-th layer (1-indexed) is global.  0 window => all global.
    local_window: int = 0
    global_every: int = 0

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # Modality stubs (spec: frontend provides precomputed embeddings).
    frontend: Optional[str] = None      # None | 'audio' | 'vision'
    n_codebooks: int = 0                # audio: EnCodec codebooks
    n_vision_tokens: int = 0            # vlm: patch-embedding count

    # ---- performance levers (hillclimbed in EXPERIMENTS.md §Perf) ----
    remat_policy: str = "full"          # none | full | dots
    attention_impl: str = "naive"       # naive | chunked  (chunked = online-softmax, O(S) memory)
    attention_chunk: int = 1024
    vocab_loss_chunk: int = 0           # 0 = dense logits; >0 = chunked logsumexp loss
    sequence_parallel: bool = False     # shard S on "model" between blocks
    time_mix_impl: str = "scan"         # rwkv wkv: scan | chunked
    rwkv_chunk: int = 64
    ssm_impl: str = "scan"              # selective scan: scan | associative | chunked
    parallel_strategy: str = "tp"       # tp (megatron) | fsdp (ZeRO-3 gather)
    scan_layers: bool = True
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    @property
    def supports_long_context(self) -> bool:
        if self.block_type in ("rwkv",):
            return True
        if self.block_type == "hybrid":
            return True
        # 5:1 local:global sliding-window counts as sub-quadratic-dominant.
        return self.local_window > 0 and self.global_every > 1

    def shapes(self) -> Tuple[str, ...]:
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context:
            names.append("long_500k")
        return tuple(names)

    # ------------------------------------------------------------------
    # Parameter counting (for MODEL_FLOPS = 6 N D in the roofline).
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, V = self.d_model, self.vocab_size
        total = V * d                                # embedding
        if not self.tie_embeddings:
            total += V * d                           # lm head
        if self.frontend == "audio" and self.n_codebooks:
            total += (self.n_codebooks - 1) * V * d  # extra heads + embeds
        per_layer = 0
        # --- attention / mixer ---
        if self.block_type in ("attn", "hybrid"):
            hd = self.head_dim
            if self.mla is not None:
                m = self.mla
                per_layer += d * m.q_lora_rank
                per_layer += m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                per_layer += self.n_heads * m.v_head_dim * d
            else:
                per_layer += d * self.n_heads * hd            # q
                per_layer += 2 * d * self.n_kv_heads * hd     # k, v
                per_layer += self.n_heads * hd * d            # o
        if self.block_type == "rwkv":
            # r,k,v,g,o projections + decay/mix loras (approx, dominated by 5 d^2)
            per_layer += 5 * d * d + 6 * d * 96
        if self.block_type == "hybrid" and self.ssm is not None:
            di = self.ssm.expand * d
            per_layer += d * 2 * di + di * d + di * (self.ssm.d_state * 2 + 1) + di * self.ssm.d_conv
        # --- ffn ---
        if self.moe is not None and self.moe.n_experts:
            e_ff = self.moe.expert_d_ff or self.d_ff
            routed = 3 * d * e_ff * self.moe.n_experts
            shared = 3 * d * e_ff * self.moe.n_shared
            router = d * self.moe.n_experts
            n_moe = self.n_layers - self.moe.first_dense_layers
            total += n_moe * (routed + shared + router)
            total += self.moe.first_dense_layers * 3 * d * self.d_ff
            if active_only:
                total -= n_moe * routed
                total += n_moe * 3 * d * e_ff * self.moe.top_k
        else:
            if self.block_type == "rwkv":
                per_layer += 2 * d * self.d_ff        # rwkv channel-mix: 2 mats
            else:
                per_layer += 3 * d * self.d_ff        # swiglu: w1, w2, w3
        total += self.n_layers * per_layer
        total += self.n_layers * 2 * d                # norms
        return int(total)

    def kv_cache_bytes(self, batch: int, seq: int, dtype_bytes: int = 2) -> int:
        """Global KV-cache (or recurrent-state) footprint for decode."""
        if self.block_type == "rwkv":
            H = self.d_model // 64
            return self.n_layers * batch * H * 64 * 64 * 4 + self.n_layers * batch * self.d_model * 4
        per_tok = 0
        if self.mla is not None:
            per_tok = self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
        else:
            per_tok = 2 * self.n_kv_heads * self.head_dim
        size = self.n_layers * batch * seq * per_tok * dtype_bytes
        if self.block_type == "hybrid" and self.ssm is not None:
            di = self.ssm.expand * self.d_model
            size += self.n_layers * batch * di * self.ssm.d_state * 4
        return int(size)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    _REGISTRY[arch_id] = full
    _SMOKE[arch_id] = smoke


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _SMOKE if smoke else _REGISTRY
    if arch_id not in table:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(table)}")
    return table[arch_id]()


def list_archs() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        granite_3_8b, minitron_8b, mistral_nemo_12b, gemma3_1b, dbrx_132b,
        deepseek_v2_236b, hymba_1_5b, musicgen_large, rwkv6_7b, internvl2_26b,
    )
