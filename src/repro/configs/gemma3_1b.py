"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global sliding-window attention (window 512), 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma3-1b", family="dense", block_type="attn",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab_size=262144, rope_theta=1_000_000.0,
        tie_embeddings=True,
        local_window=512, global_every=6,   # layers 6,12,18,24 global; rest local
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, local_window=16, global_every=2,
    )


register("gemma3-1b", full, smoke)
