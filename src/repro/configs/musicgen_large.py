"""musicgen-large [audio] — 48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens, 4 codebooks with delay interleaving.
Frontend is a STUB per task spec: ``input_specs()`` provides precomputed frame
embeddings; the model owns per-codebook LM heads. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="musicgen-large", family="audio", block_type="attn",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048, rope_theta=10_000.0,
        frontend="audio", n_codebooks=4,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, n_codebooks=2,
    )


register("musicgen-large", full, smoke)
