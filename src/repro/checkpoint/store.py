"""Checkpoint store: atomic, manifest-driven msgpack, zstd-compressed when
``zstandard`` is installed (raw msgpack otherwise — the codec is recorded in
the manifest, so mixed environments restore each other's checkpoints as long
as the reader has the writer's codec).

Layout:
  <dir>/step_000123/
    manifest.json            # tree structure, shapes, dtypes, step, codec
    arrays.msgpack.zst       # flat {key: bytes} (or arrays.msgpack, raw)
  <dir>/LATEST               # atomically-updated pointer (two-phase commit)

Restore is mesh-agnostic: arrays come back as numpy and are re-sharded by
``device_put`` against whatever mesh the restoring job runs (elastic resize
— the paper's "switch off cores" — is therefore free at the checkpoint
layer; see checkpoint/elastic.py for the plan validation).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import msgpack
import numpy as np

try:
    import zstandard as zstd
    HAVE_ZSTD = True
except ImportError:          # optional dependency — fall back to raw msgpack
    zstd = None
    HAVE_ZSTD = False

import jax
import jax.numpy as jnp

_CODEC_FILES = {"zstd": "arrays.msgpack.zst", "raw": "arrays.msgpack"}


def _encode(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        return zstd.ZstdCompressor(level=3).compress(blob)
    return blob


def _decode(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise ImportError(
                "checkpoint was written with the zstd codec but the "
                "'zstandard' package is not installed")
        return zstd.ZstdDecompressor().decompress(blob)
    return blob


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat, jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None,
         codec: Optional[str] = None) -> str:
    if codec is None:
        codec = "zstd" if HAVE_ZSTD else "raw"
    if codec not in _CODEC_FILES:
        raise ValueError(f"unknown codec {codec!r}")
    if codec == "zstd" and not HAVE_ZSTD:
        raise ImportError("codec='zstd' requires the 'zstandard' package")
    flat, _ = _flatten(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = step_dir + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "extra": extra or {}, "codec": codec,
                "arrays": {}}
    payload: Dict[str, bytes] = {}
    for key in sorted(flat):
        arr = np.asarray(flat[key])
        # bfloat16 has no numpy wire format -> store as uint16 view + tag
        tag = str(arr.dtype)
        if tag == "bfloat16":
            arr = arr.view(np.uint16)
        manifest["arrays"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                                   "orig_dtype": tag}
        payload[key] = arr.tobytes()

    with open(os.path.join(tmp, _CODEC_FILES[codec]), "wb") as f:
        f.write(_encode(msgpack.packb(payload), codec))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # two-phase commit: rename dir, then flip LATEST
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp, step_dir)
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return step_dir


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip().split("_")[-1])


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `like` (shapes validated).  If
    `shardings` (matching pytree of NamedSharding) is given, arrays are
    device_put with them — the elastic re-shard path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    codec = manifest.get("codec", "zstd")   # pre-codec checkpoints were zstd
    if codec not in _CODEC_FILES:
        raise ValueError(f"checkpoint {step_dir} uses unknown codec {codec!r}")
    with open(os.path.join(step_dir, _CODEC_FILES[codec]), "rb") as f:
        payload = msgpack.unpackb(_decode(f.read(), codec))

    flat_like, _ = _flatten(like)
    flat_shard, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for key, leaf in flat_like.items():
        meta = manifest["arrays"][key]
        raw = payload[key]
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        if meta["orig_dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if key in flat_shard:
            out[key] = jax.device_put(arr, flat_shard[key])
        else:
            out[key] = jnp.asarray(arr)
    # rebuild tree in like's structure
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]
