"""Checkpoint store: atomic, manifest-driven msgpack, zstd-compressed when
``zstandard`` is installed (raw msgpack otherwise — the codec is recorded in
the manifest, so mixed environments restore each other's checkpoints as long
as the reader has the writer's codec).

Layout:
  <dir>/step_000123/
    manifest.json            # tree structure, shapes, dtypes, step, codec
    arrays.msgpack.zst       # flat {key: bytes} (or arrays.msgpack, raw)
  <dir>/LATEST               # atomically-updated pointer (two-phase commit)

Crash-safety contract (what the SON resume path leans on): at every point
during ``save`` there is a complete checkpoint on disk that ``restore``
can open.  The commit sequence is write-to-``.tmp`` → rename the old step
aside to ``.old`` → rename ``.tmp`` into place → flip LATEST → delete
``.old``; a crash in any window leaves either the old step (possibly under
its ``.old`` name, recovered transparently on read) or the new one.  Stale
``.tmp``/``.old`` dirs from a crashed save are wiped on the next write,
never reused.

Restore is mesh-agnostic: arrays come back as numpy and are re-sharded by
``device_put`` against whatever mesh the restoring job runs (elastic resize
— the paper's "switch off cores" — is therefore free at the checkpoint
layer; see checkpoint/elastic.py for the plan validation).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

try:
    import zstandard as zstd
    HAVE_ZSTD = True
except ImportError:          # optional dependency — fall back to raw msgpack
    zstd = None
    HAVE_ZSTD = False

import jax
import jax.numpy as jnp

_CODEC_FILES = {"zstd": "arrays.msgpack.zst", "raw": "arrays.msgpack"}


def _encode(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        return zstd.ZstdCompressor(level=3).compress(blob)
    return blob


def _decode(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise ImportError(
                "checkpoint was written with the zstd codec but the "
                "'zstandard' package is not installed")
        return zstd.ZstdDecompressor().decompress(blob)
    return blob


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat, jax.tree_util.tree_structure(tree)


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:09d}")


def _is_complete(step_dir: str) -> bool:
    """The manifest is written last inside the tmp dir, so its presence
    marks a fully-written checkpoint."""
    return os.path.isfile(os.path.join(step_dir, "manifest.json"))


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None,
         codec: Optional[str] = None, keep_last: Optional[int] = None) -> str:
    """Write one checkpoint; some complete checkpoint survives a crash at
    any point.  ``keep_last=N`` prunes all but the newest N steps after the
    commit (the step LATEST points at is never pruned)."""
    if codec is None:
        codec = "zstd" if HAVE_ZSTD else "raw"
    if codec not in _CODEC_FILES:
        raise ValueError(f"unknown codec {codec!r}")
    if codec == "zstd" and not HAVE_ZSTD:
        raise ImportError("codec='zstd' requires the 'zstandard' package")
    flat, _ = _flatten(tree)
    step_dir = _step_dir(ckpt_dir, step)
    tmp = step_dir + ".tmp"
    old = step_dir + ".old"
    # a crashed save may have left a stale .tmp (half-written payloads —
    # reusing it mixes files across codecs) or a stale .old (already
    # superseded, or about to be recovered by the read below); at the start
    # of a new save neither is load-bearing, so wipe both
    if os.path.isdir(step_dir) and not _is_complete(step_dir):
        # crashed mid-commit: the half-renamed dir is garbage, the intact
        # old step (if any) is still under .old — put it back first
        shutil.rmtree(step_dir)
        if _is_complete(old):
            os.rename(old, step_dir)
    for stale in (tmp, old):
        if os.path.exists(stale):
            shutil.rmtree(stale)
    os.makedirs(tmp)

    manifest = {"step": step, "extra": extra or {}, "codec": codec,
                "arrays": {}}
    payload: Dict[str, bytes] = {}
    for key in sorted(flat):
        arr = np.asarray(flat[key])
        # bfloat16 has no numpy wire format -> store as uint16 view + tag
        tag = str(arr.dtype)
        if tag == "bfloat16":
            arr = arr.view(np.uint16)
        manifest["arrays"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                                   "orig_dtype": tag}
        payload[key] = arr.tobytes()

    with open(os.path.join(tmp, _CODEC_FILES[codec]), "wb") as f:
        f.write(_encode(msgpack.packb(payload), codec))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # commit: rename the old step ASIDE (never delete-then-rename — a crash
    # in that window would leave LATEST pointing at nothing), move the new
    # dir into place, flip LATEST, and only then drop the old step
    have_old = os.path.exists(step_dir)
    if have_old:
        os.rename(step_dir, old)
    os.rename(tmp, step_dir)
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    if have_old:
        shutil.rmtree(old)
    if keep_last is not None:
        _prune(ckpt_dir, keep_last)
    return step_dir


def _prune(ckpt_dir: str, keep_last: int) -> None:
    keep_last = max(1, int(keep_last))
    present = steps_present(ckpt_dir)
    latest = latest_step(ckpt_dir)
    for s in present[:-keep_last]:
        if s == latest:          # never prune the committed pointer target
            continue
        for suffix in ("", ".old"):
            d = _step_dir(ckpt_dir, s) + suffix
            if os.path.exists(d):
                shutil.rmtree(d)


def steps_present(ckpt_dir: str) -> List[int]:
    """Steps with a complete checkpoint on disk — including steps only
    reachable through a crashed save's ``.old`` dir (recovered on read)."""
    steps = set()
    if not os.path.isdir(ckpt_dir):
        return []
    for name in os.listdir(ckpt_dir):
        stem = name[:-4] if name.endswith(".old") else name
        if not (stem.startswith("step_") and stem[5:].isdigit()):
            continue
        if _is_complete(os.path.join(ckpt_dir, name)):
            steps.add(int(stem[5:]))
    return sorted(steps)


def _resolve_step_dir(ckpt_dir: str, step: int) -> Optional[str]:
    """Directory of a complete checkpoint for ``step``, recovering from a
    save that crashed between rename-aside and commit; None if absent."""
    d = _step_dir(ckpt_dir, step)
    if _is_complete(d):
        return d
    old = d + ".old"
    if _is_complete(old):
        # crash window: the new dir never landed (or landed half-written)
        # but the previous checkpoint is intact under .old — restore it to
        # its real name so LATEST and future saves see a normal store
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(old, d)
        return d
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    """The newest restorable step.  A LATEST pointer whose directory was
    deleted (or never committed) is not trusted — fall back to the newest
    complete checkpoint actually on disk."""
    p = os.path.join(ckpt_dir, "LATEST")
    present = steps_present(ckpt_dir)
    if os.path.exists(p):
        with open(p) as f:
            step = int(f.read().strip().split("_")[-1])
        if step in present:
            return step
    return present[-1] if present else None


def _missing_step_error(ckpt_dir: str, step: Optional[int]) -> FileNotFoundError:
    present = steps_present(ckpt_dir)
    have = ", ".join(str(s) for s in present) if present else "none"
    what = "no checkpoint" if step is None else f"checkpoint step {step} not"
    return FileNotFoundError(
        f"{what} found under {ckpt_dir} (steps present: {have})")


def _read_payload(ckpt_dir: str, step: Optional[int]
                  ) -> Tuple[Dict, Dict[str, bytes], int]:
    """Resolve + validate a step, returning (manifest, payload, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise _missing_step_error(ckpt_dir, None)
    step_dir = _resolve_step_dir(ckpt_dir, step)
    if step_dir is None:
        raise _missing_step_error(ckpt_dir, step)
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    codec = manifest.get("codec", "zstd")   # pre-codec checkpoints were zstd
    if codec not in _CODEC_FILES:
        raise ValueError(f"checkpoint {step_dir} uses unknown codec {codec!r}")
    with open(os.path.join(step_dir, _CODEC_FILES[codec]), "rb") as f:
        payload = msgpack.unpackb(_decode(f.read(), codec))
    return manifest, payload, step


def _as_array(meta: Dict, raw: bytes) -> np.ndarray:
    arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
    if meta["orig_dtype"] == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    return arr


def load_arrays(ckpt_dir: str, step: Optional[int] = None
                ) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Restore a checkpoint as a flat ``{key: writable numpy array}`` plus
    its extra dict, with no ``like`` tree — the resume path for state whose
    shapes are only known from the checkpoint itself (SON's per-level
    candidate arrays grow between boundaries)."""
    manifest, payload, _ = _read_payload(ckpt_dir, step)
    out = {}
    for key, meta in manifest["arrays"].items():
        out[key] = _as_array(meta, payload[key]).copy()   # writable
    return out, manifest["extra"]


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `like` (shapes validated).  If
    `shardings` (matching pytree of NamedSharding) is given, arrays are
    device_put with them — the elastic re-shard path."""
    manifest, payload, _ = _read_payload(ckpt_dir, step)

    flat_like, _ = _flatten(like)
    flat_shard, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for key, leaf in flat_like.items():
        arr = _as_array(manifest["arrays"][key], payload[key])
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if key in flat_shard:
            out[key] = jax.device_put(arr, flat_shard[key])
        else:
            out[key] = jnp.asarray(arr)
    # rebuild tree in like's structure
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]
