"""Elastic resize: resume a checkpoint on a different mesh.

The store keeps unsharded logical arrays, so elasticity reduces to (a)
validating the new mesh still divides every sharded dim, (b) device_put with
the new shardings, and (c) re-planning data shards via the MB scheduler.
This is the pod-scale version of the paper's "switch off the unused cores":
a shrink from (16,16) to (8,16) gates 128 chips, and the restored job
continues with re-proportioned work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.core.hetero import HeterogeneityProfile
from repro.data.sharding import BatchPlan, plan_batches
from repro.distributed import meshes


@dataclass
class ResizePlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    gated_chips: int
    batch_plan: Optional[BatchPlan] = None

    @property
    def is_shrink(self) -> bool:
        return int(np.prod(self.new_shape)) < int(np.prod(self.old_shape))


def plan_resize(old_mesh: Mesh, new_mesh: Mesh, global_batch: int,
                microbatch: int, profile: Optional[HeterogeneityProfile] = None
                ) -> ResizePlan:
    old_n = int(np.prod(list(old_mesh.shape.values())))
    new_n = int(np.prod(list(new_mesh.shape.values())))
    ndp = int(np.prod([new_mesh.shape[a] for a in meshes.batch_axes(new_mesh)]))
    prof = profile or HeterogeneityProfile.homogeneous(ndp)
    bp = plan_batches(prof, global_batch, microbatch)
    return ResizePlan(tuple(old_mesh.shape.values()), tuple(new_mesh.shape.values()),
                      gated_chips=max(old_n - new_n, 0), batch_plan=bp)


def restore_elastic(ckpt_dir: str, like: Any, cfg: ModelConfig,
                    new_mesh: Mesh, step: Optional[int] = None):
    """Restore `like`-shaped state re-sharded onto `new_mesh`."""
    specs = meshes.param_pspecs(cfg, like, new_mesh)
    shardings = meshes.named(specs, new_mesh)
    return store.restore(ckpt_dir, like, step=step, shardings=shardings)
