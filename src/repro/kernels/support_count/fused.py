"""Fused packed-popcount support-count kernel (the autotuner's second
variant for the Apriori hot loop).

The MXU kernel in :mod:`repro.kernels.support_count.kernel` spends one
int8 MAC per (transaction, candidate, item) triple.  This variant packs
the item axis into uint32 *words* (32 items per lane element) and fuses
the whole round into a single launch:

  dot(T_t, C_m) == Σ_w popcount(Tw[t, w] & Cw[m, w])

so the containment test, the candidate filter (``== |C_m|``) and the
per-tile count reduce all happen in one kernel body — no [N, M] score
matrix ever leaves the core, and the item contraction shrinks 32× in
both bytes moved and lane ops.  On VPU-heavy devices (and in interpret
mode, where the body lowers to straight XLA ops) this beats the matmul
formulation; on MXU-rich devices the matmul usually wins.  Which variant
runs where is exactly what :mod:`repro.kernels.autotune` measures.

Tiling (HBM→VMEM):
  grid = (M/bm, N/bn) — candidate tiles outermost, transaction tiles
  innermost, so each [1, bm] output block is revisited only across the
  sequential-innermost N axis (the revisit pattern TPU Pallas supports)
  and Pallas' grid pipeline double-buffers the Tw/Cw block DMAs across
  steps.  The word axis is carried whole per block: W = I/32 words is
  small (a 4096-item universe is 128 lanes), so the [bn, W] and [bm, W]
  blocks stay far below VMEM limits and the [bn, bm, W] popcount
  intermediate is the working set that bounds bn·bm.

Padding contract (shared with the MXU variant's ops wrapper): padded
transaction rows are all-zero words (support only the empty itemset,
which Apriori never emits) and padded candidate rows are sliced away by
the caller — an all-zero candidate would match every transaction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD_BITS = 32


def pack_words(x: jnp.ndarray) -> jnp.ndarray:
    """0/1 bitmap [R, I] (I % 32 == 0) -> packed uint32 words [R, I/32].

    Bit b of word w holds item ``w * 32 + b``.  jit-friendly: a reshape
    plus a shift-weighted sum, so the packing fuses into the caller's
    program instead of round-tripping through the host.
    """
    r, i = x.shape
    assert i % WORD_BITS == 0, f"item axis must be 32-aligned, got {i}"
    bits = x.astype(jnp.uint32).reshape(r, i // WORD_BITS, WORD_BITS)
    shifts = jnp.left_shift(jnp.uint32(1),
                            jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(bits * shifts, axis=2, dtype=jnp.uint32)


def _popcount_dots(t: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """[bn, W] x [bm, W] packed words -> [bn, bm] int32 AND-popcounts."""
    inter = jax.lax.population_count(t[:, None, :] & c[None, :, :])
    return jnp.sum(inter, axis=2).astype(jnp.int32)


def _kernel(t_ref, c_ref, sizes_ref, out_ref):
    """Grid: (j, i) over (M-tiles, N-tiles); N innermost (out revisits)."""
    i = pl.program_id(1)
    dots = _popcount_dots(t_ref[...], c_ref[...])          # [bn, bm]
    hits = (dots == sizes_ref[...]).astype(jnp.int32)      # filter fused in
    partial = jnp.sum(hits, axis=0, keepdims=True)         # [1, bm]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def support_count_fused_pallas(Tw: jnp.ndarray, Cw: jnp.ndarray,
                               sizes: jnp.ndarray, *, bn: int = 512,
                               bm: int = 256,
                               interpret: bool = False) -> jnp.ndarray:
    """Tw: [N, W] uint32; Cw: [M, W] uint32; sizes: [1, M] i32 -> [1, M] i32."""
    N, W = Tw.shape
    M = Cw.shape[0]
    bn, bm = min(bn, N), min(bm, M)
    assert N % bn == 0 and M % bm == 0, (Tw.shape, Cw.shape, (bn, bm))
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, W), lambda j, i: (i, 0)),
            pl.BlockSpec((bm, W), lambda j, i: (j, 0)),
            pl.BlockSpec((1, bm), lambda j, i: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, M), jnp.int32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(Tw, Cw, sizes)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def support_count_fused(T: jnp.ndarray, C: jnp.ndarray, *, bn: int = 512,
                        bm: int = 256,
                        interpret: bool = False) -> jnp.ndarray:
    """Unpacked 0/1 bitmaps in, fused counts out: packs on device (fuses
    into this jit), derives |C_m|, runs the kernel.  T: [N, I] int8/uint8,
    C: [M, I] — both item-axes 32-aligned; returns [1, M] int32."""
    sizes = jnp.sum(C.astype(jnp.int32), axis=1)[None, :]      # [1, M]
    return support_count_fused_pallas(pack_words(T), pack_words(C), sizes,
                                      bn=bn, bm=bm, interpret=interpret)
