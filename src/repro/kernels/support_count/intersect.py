"""Fused AND-popcount tid-slab intersection kernel (the Eclat primitive).

The Apriori fused kernel (:mod:`.fused`) intersects *candidate rows
against transaction rows*; the vertical (Eclat) formulation instead
intersects *two candidate tid-slabs against each other*: row m of A
holds the packed uint32 tid-list of one (k-1)-subset, row m of B the
tid-list of the sibling subset from the F_{k-1} ⋈ F_{k-1} join, and

  support(candidate m) = Σ_w popcount(A[m, w] & B[m, w])

— a pure row-aligned VPU op with no cross-row contraction at all, which
is why Eclat wins on dense data: the transaction axis was paid for once
at columnization and every later round touches only |candidates| × W
words instead of n_tx × n_items lanes.

Tiling (HBM→VMEM):
  grid = (M/bm, W/bw) — candidate tiles outermost, word tiles innermost,
  so each [1, bm] output block is revisited only across the
  sequential-innermost word axis (the same revisit pattern the Apriori
  fused kernel uses over its transaction axis) and the A/B block DMAs
  double-buffer across steps.

Padding contract: padded candidate rows and padded word lanes are
all-zero, so they contribute popcount 0 — inert, the caller just slices
rows.  (No ``sizes`` input is needed: there is no containment filter,
the popcount IS the support.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, out_ref):
    """Grid: (j, i) over (M-tiles, W-tiles); W innermost (out revisits)."""
    i = pl.program_id(1)
    inter = jax.lax.population_count(a_ref[...] & b_ref[...])   # [bm, bw]
    partial = jnp.sum(inter.astype(jnp.int32), axis=1)[None, :]  # [1, bm]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bm", "bw", "interpret"))
def intersect_count_pallas(A: jnp.ndarray, B: jnp.ndarray, *,
                           bm: int = 256, bw: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """A, B: [M, W] packed uint32 tid-slabs -> [1, M] int32 popcounts."""
    M, W = A.shape
    assert B.shape == (M, W), (A.shape, B.shape)
    bm, bw = min(bm, M), min(bw, W)
    assert M % bm == 0 and W % bw == 0, (A.shape, (bm, bw))
    grid = (M // bm, W // bw)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda j, i: (j, i)),
            pl.BlockSpec((bm, bw), lambda j, i: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, M), jnp.int32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(A, B)
