"""Pallas TPU kernel: candidate-itemset support counting on the MXU.

The paper's compute hot-spot (Apriori step 2) adapted to TPU: transactions
are a 0/1 bitmap ``T[N, I]`` and candidates a bitmask ``C[M, I]``; support
is ``Σ_t 1[dot(T_t, C_m) == |C_m|]``.  The containment test becomes one
int-matmul on the systolic array plus a VPU compare — arithmetic intensity
is that of a matmul, so the kernel is compute-roofline-bound instead of the
byte-bound scalar hash-tree walk the paper's CPU cores would run.

Tiling (HBM→VMEM):
  grid = (N/bn, M/bm, I/bi)  — item (contraction) axis innermost so the
  [bn, bm] f32 accumulator lives in VMEM scratch across the k-loop; on the
  last item-tile we compare against |C_m| and fold the per-tile counts into
  the [1, bm] int32 output block (output revisited across the N-axis, which
  is the sequential-innermost-revisit pattern TPU Pallas supports).

Block defaults (bn=512, bm=256, bi=512, int8 inputs):
  VMEM ≈ 512·512 (T) + 512·256 (C) + 512·256·4 (acc f32) + small ≈ 1.4 MiB ✓
  MXU: 512×512×256 int8 dots, lane-aligned (128 | bi, bm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(t_ref, c_ref, sizes_ref, out_ref, acc_ref):
    """Grid: (i, j, l) over (N-tiles, M-tiles, I-tiles)."""
    l = pl.program_id(2)
    nl = pl.num_programs(2)

    @pl.when(l == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> f32 accumulate on the MXU
    acc_ref[...] += jax.lax.dot_general(
        t_ref[...], c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    i = pl.program_id(0)

    @pl.when(l == nl - 1)
    def _finalize():
        sizes = sizes_ref[...]                       # [1, bm] f32
        hits = (acc_ref[...] == sizes).astype(jnp.int32)   # [bn, bm]
        partial = jnp.sum(hits, axis=0, keepdims=True)     # [1, bm]

        @pl.when(i == 0)
        def _init():
            out_ref[...] = partial

        @pl.when(i != 0)
        def _accum():
            out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bi", "interpret"))
def support_count_pallas(T: jnp.ndarray, C: jnp.ndarray, sizes: jnp.ndarray,
                         *, bn: int = 512, bm: int = 256, bi: int = 512,
                         interpret: bool = False) -> jnp.ndarray:
    """T: [N, I] int8; C: [M, I] int8; sizes: [1, M] f32 (=|C_m|) -> [1, M] i32."""
    N, I = T.shape
    M = C.shape[0]
    bn, bm, bi = min(bn, N), min(bm, M), min(bi, I)
    assert N % bn == 0 and M % bm == 0 and I % bi == 0, (T.shape, C.shape, (bn, bm, bi))
    grid = (N // bn, M // bm, I // bi)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bi), lambda i, j, l: (i, l)),
            pl.BlockSpec((bm, bi), lambda i, j, l: (j, l)),
            pl.BlockSpec((1, bm), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda i, j, l: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, M), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(T, C, sizes)
