"""Jit'd public wrapper for the support-count kernel (handles padding and
backend selection: Pallas-TPU on TPU, interpret-mode elsewhere)."""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.support_count.kernel import support_count_pallas
from repro.kernels.support_count.ref import support_count_ref


def _pad_to(x: jnp.ndarray, axis: int, multiple: int):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def support_count(T: jnp.ndarray, C: jnp.ndarray, *,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Support counts [M] int32.  Pads N→8·, M→128·, I→128· as the kernel
    requires; padded candidate rows have |c|=0 and are sliced away (a padded
    all-zero candidate would match every row, so we must slice, not rely on
    zero counts)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N0, M0 = T.shape[0], C.shape[0]
    T = _pad_to(_pad_to(T.astype(jnp.int8), 1, 128), 0, 8)
    C = _pad_to(_pad_to(C.astype(jnp.int8), 1, 128), 0, 128)
    sizes = C.astype(jnp.float32).sum(axis=1)[None, :]          # [1, M]
    bn = min(512, T.shape[0])
    bm = min(256, C.shape[0])
    bi = min(512, T.shape[1])
    # grid-divisibility: shrink blocks to gcd-friendly sizes
    while T.shape[0] % bn:
        bn //= 2
    while C.shape[0] % bm:
        bm //= 2
    while T.shape[1] % bi:
        bi //= 2
    out = support_count_pallas(T, C, sizes, bn=bn, bm=bm, bi=bi,
                               interpret=interpret)
    counts = out[0, :M0]
    # padded transaction rows are all-zero: they can only match |c|=0 sets,
    # which do not occur among real candidates (Apriori starts at k=1).
    return counts


support_count_oracle = support_count_ref
