"""Jit'd public wrapper for the support-count kernel family (handles
padding, backend selection and autotuned variant/tile dispatch).

Two implementations compute the same counts bit-identically:

* ``mxu``    — the int8-matmul kernel (:mod:`.kernel`): containment as a
  systolic-array dot plus a VPU compare.
* ``packed`` — the fused packed-popcount kernel (:mod:`.fused`): items
  packed 32-per-uint32-word, containment + filter + count in one launch.

Which one runs — and at what tile shape — comes from the autotune cache
(:mod:`repro.kernels.autotune`) keyed by (kernel, shape-bucket, device
kind); with no cache entry the roofline-seeded default applies.  Off-TPU
both run in interpret mode (lowered to plain XLA ops), which is where
the CI baselines hold the packed variant to *beating* the jitted ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.autotune.cache import resolve_config
from repro.kernels.support_count.fused import support_count_fused
from repro.kernels.support_count.intersect import intersect_count_pallas
from repro.kernels.support_count.kernel import support_count_pallas
from repro.kernels.support_count.ref import intersect_count_ref, support_count_ref


def _pad_to(x: jnp.ndarray, axis: int, multiple: int):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fit(want: int, dim: int) -> int:
    """Shrink a cached/heuristic tile until it divides the padded dim."""
    t = max(1, min(int(want), dim))
    while dim % t:
        t //= 2
    return max(t, 1)


def support_count(T: jnp.ndarray, C: jnp.ndarray, *,
                  interpret: bool | None = None,
                  tuning=None) -> jnp.ndarray:
    """Support counts [M] int32.  Pads N→8·, M→128·, I→128· as the kernels
    require; padded candidate rows have |c|=0 and are sliced away (a padded
    all-zero candidate would match every row, so we must slice, not rely on
    zero counts).

    ``tuning``: ``None`` = the checked-in autotune cache; ``False`` =
    roofline-seeded default config; a config ``dict`` or an
    ``AutotuneCache`` pins the choice (tests, the tuner, CI sweeps).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N0, M0 = T.shape[0], C.shape[0]
    if M0 == 0:          # empty candidate level: nothing to count
        return jnp.zeros((0,), jnp.int32)
    T = _pad_to(_pad_to(T.astype(jnp.int8), 1, 128), 0, 8)
    C = _pad_to(_pad_to(C.astype(jnp.int8), 1, 128), 0, 128)
    N, I = T.shape
    M = C.shape[0]
    cfg = resolve_config("support_count", (N, M, I), tuning)
    bn = _fit(cfg.get("bn", 512), N)
    bm = _fit(cfg.get("bm", 256), M)
    if cfg.get("variant", "mxu") == "packed":
        out = support_count_fused(T, C, bn=bn, bm=bm, interpret=interpret)
    else:
        sizes = C.astype(jnp.float32).sum(axis=1)[None, :]      # [1, M]
        bi = _fit(cfg.get("bi", 512), I)
        out = support_count_pallas(T, C, sizes, bn=bn, bm=bm, bi=bi,
                                   interpret=interpret)
    counts = out[0, :M0]
    # padded transaction rows are all-zero: they can only match |c|=0 sets,
    # which do not occur among real candidates (Apriori starts at k=1).
    return counts


def intersect_count(A: jnp.ndarray, B: jnp.ndarray, *,
                    interpret: bool | None = None,
                    tuning=None) -> jnp.ndarray:
    """Row-aligned tid-slab intersection counts [M] int32 (Eclat primitive).

    A, B: [M, W] packed uint32 tid-lists — row m of the output is
    |tidset(A[m]) ∩ tidset(B[m])|.  Pads M→128·, W→128· with zero words
    (inert: popcount(0) == 0) and slices padded rows away.

    ``tuning`` follows the family contract: ``None`` = the checked-in
    autotune cache; ``False`` = roofline-seeded default config; a config
    ``dict`` or an ``AutotuneCache`` pins the choice.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if A.shape != B.shape:
        raise ValueError(f"slab shapes differ: {A.shape} vs {B.shape}")
    M0 = A.shape[0]
    if M0 == 0:          # empty candidate level: nothing to intersect
        return jnp.zeros((0,), jnp.int32)
    A = _pad_to(_pad_to(A.astype(jnp.uint32), 1, 128), 0, 128)
    B = _pad_to(_pad_to(B.astype(jnp.uint32), 1, 128), 0, 128)
    M, W = A.shape
    cfg = resolve_config("intersect_count", (M, W), tuning)
    bm = _fit(cfg.get("bm", 256), M)
    bw = _fit(cfg.get("bw", 128), W)
    out = intersect_count_pallas(A, B, bm=bm, bw=bw, interpret=interpret)
    return out[0, :M0]


support_count_oracle = support_count_ref
intersect_count_oracle = intersect_count_ref
