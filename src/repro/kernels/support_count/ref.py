"""Pure-jnp oracle for the support-count kernel."""
from __future__ import annotations

import jax.numpy as jnp


def support_count_ref(T: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """T: [N, I] uint8/int8 0/1 transactions; C: [M, I] 0/1 candidate masks.

    support[m] = #{ t : T[t] ∧ C[m] == C[m] }  (itemset containment count)
    """
    dots = jnp.dot(T.astype(jnp.int32), C.astype(jnp.int32).T)      # [N, M]
    sizes = C.astype(jnp.int32).sum(axis=1)                          # [M]
    return (dots == sizes[None, :]).astype(jnp.int32).sum(axis=0)    # [M]
