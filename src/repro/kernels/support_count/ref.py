"""Pure-jnp oracle for the support-count kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def support_count_ref(T: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """T: [N, I] uint8/int8 0/1 transactions; C: [M, I] 0/1 candidate masks.

    support[m] = #{ t : T[t] ∧ C[m] == C[m] }  (itemset containment count)
    """
    dots = jnp.dot(T.astype(jnp.int32), C.astype(jnp.int32).T)      # [N, M]
    sizes = C.astype(jnp.int32).sum(axis=1)                          # [M]
    return (dots == sizes[None, :]).astype(jnp.int32).sum(axis=0)    # [M]


def intersect_count_ref(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """A, B: [M, W] packed uint32 tid-slabs (bit b of word w = tid 32w+b).

    counts[m] = |tidset(A[m]) ∩ tidset(B[m])| = Σ_w popcount(A[m,w] & B[m,w])
    """
    inter = jax.lax.population_count(A & B)                          # [M, W]
    return jnp.sum(inter.astype(jnp.int32), axis=1)                  # [M]
