"""Pure-jnp oracle for the rule-match kernel family.

Serving semantics (shared by this oracle, the Pallas kernel + ops wrapper,
the serving engine, and the brute-force test oracle in
``repro.serving.oracle``):

  score[q, r] = confidence[r]  if antecedent_r ⊆ basket_q  else 0
  item[q, j]  = max over rows r with consequent[r] == j of score[q, r]
                (0 when no matching rule names j)
  items already in basket_q — and lane-padding item ids — score -1,
  so they can never enter the top-k
  top-k per query ordered by (score desc, item id asc) — lax.top_k's
  lower-index-first tie rule

Index padding contract: padded rule rows carry ``sizes = -1`` (an all-zero
antecedent row would otherwise subset-match every basket), ``conf = 0`` and
``cons = n_items_padded`` (a dummy segment sliced away before the top-k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rule_scores_ref(Q: jnp.ndarray, A: jnp.ndarray, sizes: jnp.ndarray,
                    conf: jnp.ndarray) -> jnp.ndarray:
    """Q: [B, I] 0/1 baskets; A: [R, I] 0/1 antecedent masks; sizes: [R] f32
    (=|A_r|, -1 on padded rows); conf: [R] f32 -> [B, R] f32 scores."""
    dots = jnp.dot(Q.astype(jnp.int32), A.astype(jnp.int32).T)       # [B, R]
    match = dots.astype(jnp.float32) == sizes[None, :].astype(jnp.float32)
    return match.astype(jnp.float32) * conf[None, :].astype(jnp.float32)


def topk_from_scores(scores: jnp.ndarray, Q: jnp.ndarray, cons: jnp.ndarray,
                     n_items, k: int):
    """Rule scores [B, R] -> per-item max-confidence -> top-k.

    The single definition of the post-matching semantics: both the jnp
    oracle and the Pallas ops wrapper fold their score matrices through
    this, so the two backends cannot drift apart.
    """
    Ip = Q.shape[1]
    seg = jax.vmap(
        lambda s: jax.ops.segment_max(s, cons, num_segments=Ip + 1))(scores)
    item_scores = jnp.maximum(seg[:, :Ip], 0.0)   # empty segments -> 0
    valid = (jnp.arange(Ip)[None, :] < n_items) & (Q == 0)
    masked = jnp.where(valid, item_scores, -1.0)
    top_scores, top_items = jax.lax.top_k(masked, k)
    return top_items.astype(jnp.int32), top_scores


def recommend_ref(Q: jnp.ndarray, A: jnp.ndarray, sizes: jnp.ndarray,
                  conf: jnp.ndarray, cons: jnp.ndarray, n_items, k: int):
    """Full oracle: rule scores -> per-item max-confidence -> top-k.

    cons: [R] int32 consequent item id per rule row (n_items_padded on
    padded rows).  Returns (items [B, k] int32, scores [B, k] f32).
    """
    scores = rule_scores_ref(Q, A, sizes, conf)                       # [B, R]
    return topk_from_scores(scores, Q, cons, n_items, k)
