"""Jit'd public wrapper for the rule-match kernel family: batched top-k
recommendation (handles padding, backend selection and autotuned
variant/tile dispatch — the same idiom as the mining data plane in
``repro.pipeline.dataplane``).

Two score implementations compute bit-identical [B, R] matrices:

* ``mxu``    — the int8-matmul kernel (:mod:`.kernel`).
* ``packed`` — the fused packed-popcount kernel (:mod:`.fused`): subset
  test + confidence weighting in one launch over uint32 item words.

The variant + tile shape come from the autotune cache
(:mod:`repro.kernels.autotune`); cache misses use the roofline-seeded
default.  Either way the scores fold through the shared
``topk_from_scores``, so the backends cannot drift on serving semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.autotune.cache import resolve_config
from repro.kernels.rule_match.fused import rule_scores_fused
from repro.kernels.rule_match.kernel import rule_scores_pallas
from repro.kernels.rule_match.ref import (recommend_ref, rule_scores_ref,
                                          topk_from_scores)


def _pad_axis_to(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fit(want: int, dim: int) -> int:
    """Shrink a cached/heuristic tile until it divides the padded dim."""
    t = max(1, min(int(want), dim))
    while dim % t:
        t //= 2
    return max(t, 1)


@functools.partial(jax.jit,
                   static_argnames=("k", "backend", "variant", "interpret",
                                    "bb", "br", "bi"))
def _rule_topk(Q, A, sizes, conf, cons, n_items, *, k, backend, variant,
               interpret, bb, br, bi):
    if backend == "pallas" and variant == "packed":
        scores = rule_scores_fused(Q, A, sizes[None, :], conf[None, :],
                                   bb=bb, br=br, interpret=interpret)
    elif backend == "pallas":
        scores = rule_scores_pallas(Q, A, sizes[None, :], conf[None, :],
                                    bb=bb, br=br, bi=bi, interpret=interpret)
    else:
        scores = rule_scores_ref(Q, A, sizes, conf)
    return topk_from_scores(scores, Q, cons, n_items, k)


def rule_topk(Q: jnp.ndarray, A: jnp.ndarray, sizes: jnp.ndarray,
              conf: jnp.ndarray, cons: jnp.ndarray, *, k: int, n_items: int,
              backend: str | None = None,
              interpret: bool | None = None,
              tuning=None):
    """Top-k item recommendations for a batch of query baskets.

    Q: [B, I] 0/1 baskets; A: [R, I] 0/1 antecedent masks; sizes: [R]
    (=|A_r|); conf: [R] rule confidences; cons: [R] consequent item ids.
    Pads B→8·, R→128·, I→128· as the kernels require — padded rule rows
    get ``sizes=-1`` (never match; an all-zero row would match everything),
    ``conf=0`` and ``cons=I_padded`` (a dummy max-segment sliced away).
    An all-padding index (R=0) still scores: every query simply matches
    nothing.  Returns (items [B, k] int32, scores [B, k] f32) ordered by
    (score desc, item id asc); entries with score <= 0 are non-matches the
    caller should drop.

    ``tuning``: ``None`` = the checked-in autotune cache; ``False`` =
    roofline-seeded default config; a config ``dict`` or an
    ``AutotuneCache`` pins the choice.
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B0, I0 = Q.shape
    R0 = A.shape[0]
    if not 0 < k <= I0:
        raise ValueError(f"k={k} must be in [1, n_query_items={I0}]")
    if n_items > I0 or A.shape[1] != I0:
        raise ValueError(f"item-axis mismatch: Q {Q.shape}, A {A.shape}, "
                         f"n_items={n_items}")
    Ip = I0 + (-I0) % 128
    Q = _pad_axis_to(jnp.asarray(Q, jnp.int8), 1, Ip)
    Q = _pad_axis_to(Q, 0, B0 + (-B0) % 8)
    A = _pad_axis_to(jnp.asarray(A, jnp.int8), 1, Ip)
    # an empty rule set still pads to one full lane block of never-match
    # rows so the kernel grid stays non-degenerate
    Rp = max(R0 + (-R0) % 128, 128)
    A = _pad_axis_to(A, 0, Rp)
    pad_r = Rp - R0
    sizes = jnp.pad(jnp.asarray(sizes, jnp.float32), (0, pad_r),
                    constant_values=-1.0)
    conf = jnp.pad(jnp.asarray(conf, jnp.float32), (0, pad_r))
    cons = jnp.pad(jnp.asarray(cons, jnp.int32), (0, pad_r),
                   constant_values=Ip)
    B, _ = Q.shape
    cfg = resolve_config("rule_match", (B, Rp, Ip), tuning)
    bb = _fit(cfg.get("bb", 256), B)
    br = _fit(cfg.get("br", 256), Rp)
    bi = _fit(cfg.get("bi", 512), Ip)
    items, scores = _rule_topk(Q, A, sizes, conf, cons, n_items, k=k,
                               backend=backend,
                               variant=cfg.get("variant", "mxu"),
                               interpret=interpret, bb=bb, br=br, bi=bi)
    return items[:B0], scores[:B0]


rule_topk_oracle = recommend_ref
