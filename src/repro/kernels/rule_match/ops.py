"""Jit'd public wrapper for the rule-match kernel family: batched top-k
recommendation (handles padding and backend selection: Pallas-TPU on TPU,
jitted pure-jnp ref elsewhere — the same dispatch idiom as the mining
data plane in ``repro.pipeline.dataplane``)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rule_match.kernel import rule_scores_pallas
from repro.kernels.rule_match.ref import (recommend_ref, rule_scores_ref,
                                          topk_from_scores)


def _pad_axis_to(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("k", "backend", "interpret",
                                    "bb", "br", "bi"))
def _rule_topk(Q, A, sizes, conf, cons, n_items, *, k, backend, interpret,
               bb, br, bi):
    if backend == "pallas":
        scores = rule_scores_pallas(Q, A, sizes[None, :], conf[None, :],
                                    bb=bb, br=br, bi=bi, interpret=interpret)
    else:
        scores = rule_scores_ref(Q, A, sizes, conf)
    return topk_from_scores(scores, Q, cons, n_items, k)


def rule_topk(Q: jnp.ndarray, A: jnp.ndarray, sizes: jnp.ndarray,
              conf: jnp.ndarray, cons: jnp.ndarray, *, k: int, n_items: int,
              backend: str | None = None,
              interpret: bool | None = None):
    """Top-k item recommendations for a batch of query baskets.

    Q: [B, I] 0/1 baskets; A: [R, I] 0/1 antecedent masks; sizes: [R]
    (=|A_r|); conf: [R] rule confidences; cons: [R] consequent item ids.
    Pads B→8·, R→128·, I→128· as the kernel requires — padded rule rows
    get ``sizes=-1`` (never match; an all-zero row would match everything),
    ``conf=0`` and ``cons=I_padded`` (a dummy max-segment sliced away).
    Returns (items [B, k] int32, scores [B, k] f32) ordered by
    (score desc, item id asc); entries with score <= 0 are non-matches the
    caller should drop.
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B0, I0 = Q.shape
    R0 = A.shape[0]
    if not 0 < k <= I0:
        raise ValueError(f"k={k} must be in [1, n_query_items={I0}]")
    if n_items > I0 or A.shape[1] != I0:
        raise ValueError(f"item-axis mismatch: Q {Q.shape}, A {A.shape}, "
                         f"n_items={n_items}")
    Ip = I0 + (-I0) % 128
    Q = _pad_axis_to(jnp.asarray(Q, jnp.int8), 1, Ip)
    Q = _pad_axis_to(Q, 0, B0 + (-B0) % 8)
    A = _pad_axis_to(jnp.asarray(A, jnp.int8), 1, Ip)
    Rp = R0 + (-R0) % 128
    A = _pad_axis_to(A, 0, Rp)
    pad_r = Rp - R0
    sizes = jnp.pad(jnp.asarray(sizes, jnp.float32), (0, pad_r),
                    constant_values=-1.0)
    conf = jnp.pad(jnp.asarray(conf, jnp.float32), (0, pad_r))
    cons = jnp.pad(jnp.asarray(cons, jnp.int32), (0, pad_r),
                   constant_values=Ip)
    # grid-divisibility: shrink blocks to gcd-friendly sizes
    bb, br, bi = min(256, Q.shape[0]), min(256, Rp), min(512, Ip)
    while Q.shape[0] % bb:
        bb //= 2
    while Rp % br:
        br //= 2
    while Ip % bi:
        bi //= 2
    items, scores = _rule_topk(Q, A, sizes, conf, cons, n_items, k=k,
                               backend=backend, interpret=interpret,
                               bb=bb, br=br, bi=bi)
    return items[:B0], scores[:B0]


rule_topk_oracle = recommend_ref
