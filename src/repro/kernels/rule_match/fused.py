"""Fused packed-popcount rule-match kernel (the serving twin of
:mod:`repro.kernels.support_count.fused`).

Same trade as on the mining plane: the MXU variant prices the antecedent
containment test as one int8 matmul; this variant packs the item axis
into uint32 words and computes

  dot(Q_q, A_r) == Σ_w popcount(Qw[q, w] & Aw[r, w])

with the subset filter (``== |A_r|``) and the confidence weighting fused
into the same kernel body — one launch per batch, a 32× smaller item
contraction, no unweighted match matrix materialized.  The autotuner
(:mod:`repro.kernels.autotune`) decides per device which variant serves.

Tiling (HBM→VMEM):
  grid = (B/bb, R/br): each [bb, br] output block is owned by exactly one
  grid point (no revisits), so both axes are parallel and Pallas' grid
  pipeline double-buffers the block DMAs.  The word axis rides whole per
  block (W = I/32 is lanes-small), bounding VMEM by the [bb, br, W]
  popcount intermediate.

Padding contract (identical to the MXU variant): padded rule rows carry
``sizes = -1`` so they can never match — popcounts are >= 0 — and
``conf = 0``; padded query rows are all-zero words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.support_count.fused import _popcount_dots, pack_words

__all__ = ["pack_words", "rule_scores_fused_pallas", "rule_scores_fused"]


def _kernel(q_ref, a_ref, sizes_ref, conf_ref, out_ref):
    """Grid: (i, j) over (B-tiles, R-tiles); every block owned once."""
    dots = _popcount_dots(q_ref[...], a_ref[...])           # [bb, br] i32
    match = (dots == sizes_ref[...]).astype(jnp.float32)    # -1 never hits
    out_ref[...] = match * conf_ref[...]


@functools.partial(jax.jit, static_argnames=("bb", "br", "interpret"))
def rule_scores_fused_pallas(Qw: jnp.ndarray, Aw: jnp.ndarray,
                             sizes: jnp.ndarray, conf: jnp.ndarray, *,
                             bb: int = 256, br: int = 256,
                             interpret: bool = False) -> jnp.ndarray:
    """Qw: [B, W] uint32; Aw: [R, W] uint32; sizes: [1, R] i32;
    conf: [1, R] f32 -> [B, R] f32 confidence-weighted match scores."""
    B, W = Qw.shape
    R = Aw.shape[0]
    bb, br = min(bb, B), min(br, R)
    assert B % bb == 0 and R % br == 0, (Qw.shape, Aw.shape, (bb, br))
    grid = (B // bb, R // br)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, W), lambda i, j: (i, 0)),
            pl.BlockSpec((br, W), lambda i, j: (j, 0)),
            pl.BlockSpec((1, br), lambda i, j: (0, j)),
            pl.BlockSpec((1, br), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, br), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, R), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(Qw, Aw, sizes, conf)


@functools.partial(jax.jit, static_argnames=("bb", "br", "interpret"))
def rule_scores_fused(Q: jnp.ndarray, A: jnp.ndarray, sizes: jnp.ndarray,
                      conf: jnp.ndarray, *, bb: int = 256, br: int = 256,
                      interpret: bool = False) -> jnp.ndarray:
    """Unpacked 0/1 bitmaps in, scores out: packs on device (fuses into
    this jit).  Q: [B, I] int8; A: [R, I] int8 (item axes 32-aligned);
    sizes/conf: [1, R] f32 per the index padding contract."""
    sizes_i = sizes.astype(jnp.int32)        # -1 padding survives the cast
    return rule_scores_fused_pallas(pack_words(Q), pack_words(A), sizes_i,
                                    conf.astype(jnp.float32),
                                    bb=bb, br=br, interpret=interpret)
