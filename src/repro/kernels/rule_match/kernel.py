"""Pallas TPU kernel: batched basket × rule-antecedent subset matching.

The serving hot-spot, shaped like :mod:`repro.kernels.support_count` but
with the opposite output: the mining kernel reduces over transactions to a
per-candidate count, while serving keeps the full score matrix — one
confidence-weighted row per query basket, later max-segmented into item
scores and top-k'd by the ops wrapper.

Queries are a 0/1 bitmap ``Q[B, I]`` and rule antecedents a bitmask
``A[R, I]``; row r matches basket q iff ``dot(Q_q, A_r) == |A_r|``.  The
containment test is one int-matmul on the systolic array plus a VPU
compare/select, so batched serving inherits matmul arithmetic intensity.

Tiling (HBM→VMEM):
  grid = (B/bb, R/br, I/bi) — item (contraction) axis innermost so the
  [bb, br] f32 accumulator lives in VMEM scratch across the k-loop; on the
  last item-tile we compare against |A_r| and write the confidence-weighted
  match block straight to the [bb, br] output tile (each output block is
  owned by exactly one (i, j), so no cross-grid revisits).

Block defaults (bb=256, br=256, bi=512, int8 inputs):
  VMEM ≈ 256·512 (Q) + 256·512 (A) + 256·256·4 (acc f32) + 256·256·4 (out)
       + small ≈ 0.8 MiB ✓; MXU 256×512×256 int8 dots, lane-aligned.

Padding contract (enforced by ops.py / the rule index): padded rule rows
carry ``sizes = -1`` so they can never match (an all-zero antecedent would
otherwise match every basket with dot == |A| == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, a_ref, sizes_ref, conf_ref, out_ref, acc_ref):
    """Grid: (i, j, l) over (B-tiles, R-tiles, I-tiles)."""
    l = pl.program_id(2)
    nl = pl.num_programs(2)

    @pl.when(l == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> f32 accumulate on the MXU
    acc_ref[...] += jax.lax.dot_general(
        q_ref[...], a_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(l == nl - 1)
    def _finalize():
        match = (acc_ref[...] == sizes_ref[...]).astype(jnp.float32)  # [bb, br]
        out_ref[...] = match * conf_ref[...]


@functools.partial(jax.jit, static_argnames=("bb", "br", "bi", "interpret"))
def rule_scores_pallas(Q: jnp.ndarray, A: jnp.ndarray, sizes: jnp.ndarray,
                       conf: jnp.ndarray, *, bb: int = 256, br: int = 256,
                       bi: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Q: [B, I] int8; A: [R, I] int8; sizes/conf: [1, R] f32 -> [B, R] f32."""
    B, I = Q.shape
    R = A.shape[0]
    bb, br, bi = min(bb, B), min(br, R), min(bi, I)
    assert B % bb == 0 and R % br == 0 and I % bi == 0, (Q.shape, A.shape,
                                                        (bb, br, bi))
    grid = (B // bb, R // br, I // bi)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bi), lambda i, j, l: (i, l)),
            pl.BlockSpec((br, bi), lambda i, j, l: (j, l)),
            pl.BlockSpec((1, br), lambda i, j, l: (0, j)),
            pl.BlockSpec((1, br), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, br), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, R), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, br), jnp.float32)],
        interpret=interpret,
    )(Q, A, sizes, conf)
