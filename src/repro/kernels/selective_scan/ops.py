"""Jit'd public wrapper for the chunked selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.selective_scan.kernel import selective_scan_pallas


def selective_scan(a, b, C, h0=None, *, chunk: int = 16, d_blk: int = 64,
                   interpret: bool | None = None):
    """h_t = a_t⊙h_{t-1} + b_t; y_t = C_t·h_t.  a,b: [B,T,D,N]; C: [B,T,N]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, D, N = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)
    return selective_scan_pallas(a, b, C, h0, chunk=chunk, d_blk=d_blk,
                                 interpret=interpret)
