"""Pallas TPU kernel: chunked selective scan (Mamba/Hymba SSM hot-spot).

EXPERIMENTS.md §Perf A found the jnp floor for the Hymba SSM branch: the
log-depth ``associative_scan`` makes ~log₂S full passes over the [B,S,D,N]
tensors (47 s memory term for train_4k).  This kernel is the fused form that
floor analysis projected: the [d_blk, N] state lives in VMEM across the
chunk loop, inputs are read once and y written once — ~2 HBM passes total.

Within a chunk of c steps the recurrence h_t = a_t⊙h_{t-1} + b_t expands to

    h_t = P_t ⊙ h₀ + Σ_{s≤t} exp(logP_t − logP_s) ⊙ b_s ,  P_t = Π_{τ≤t} a_τ

computed with the exact masked-exponent form (every exponent ≤ 0 — no
1/P underflow; same trick as the WKV kernel).

Grid = (B, D/d_blk, T/c), chunk innermost; VMEM per step:
  a,b blocks 2·[c,d_blk,N] f32 + pairwise [c,c,d_blk,N] + state [d_blk,N]
  (c=16, d_blk=64, N=16 → ≈ 1.3 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(a_ref, b_ref, c_ref, h0_ref, y_ref, hfin_ref, h_scr,
            *, c: int, d_blk: int, n: int):
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _load():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)              # [c, d_blk, N]
    b = b_ref[0].astype(jnp.float32)
    Cc = c_ref[0].astype(jnp.float32)             # [c, N]

    la = jnp.log(a)
    logP = jnp.cumsum(la, axis=0)                 # inclusive, ≤ 0 rows
    P = jnp.exp(logP)

    # pairwise decay weights, exponent masked BEFORE exp (exact, safe)
    Dst = logP[:, None] - logP[None, :]           # [c, c, d_blk, N]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    Dst = jnp.where((si <= ti)[:, :, None, None], Dst, NEG_INF)
    W = jnp.exp(Dst)

    h0 = h_scr[...]
    h = P * h0[None] + jnp.einsum("tsdn,sdn->tdn", W, b)
    y = jnp.einsum("tdn,tn->td", h, Cc)           # [c, d_blk]
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = h[c - 1]

    @pl.when(t == nt - 1)
    def _emit():
        hfin_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "d_blk", "interpret"))
def selective_scan_pallas(a, b, C, h0, *, chunk: int = 16, d_blk: int = 64,
                          interpret: bool = False):
    """a, b: [B, T, D, N] f32; C: [B, T, N]; h0: [B, D, N].
    Returns (y [B, T, D] f32, h_last [B, D, N] f32)."""
    B, T, D, N = a.shape
    c = min(chunk, T)
    dk = min(d_blk, D)
    assert T % c == 0 and D % dk == 0, (T, c, D, dk)
    grid = (B, D // dk, T // c)
    y, hfin = pl.pallas_call(
        functools.partial(_kernel, c=c, d_blk=dk, n=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dk, N), lambda bi, j, t: (bi, t, j, 0)),
            pl.BlockSpec((1, c, dk, N), lambda bi, j, t: (bi, t, j, 0)),
            pl.BlockSpec((1, c, N), lambda bi, j, t: (bi, t, 0)),
            pl.BlockSpec((1, dk, N), lambda bi, j, t: (bi, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dk), lambda bi, j, t: (bi, t, j)),
            pl.BlockSpec((1, dk, N), lambda bi, j, t: (bi, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, N), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), C.astype(jnp.float32),
      h0.astype(jnp.float32))
    return y, hfin
