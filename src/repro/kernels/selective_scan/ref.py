"""Pure-jnp oracle for the chunked selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(a, b, C, h0=None):
    """h_t = a_t ⊙ h_{t-1} + b_t ;  y_t = Σ_n C_t[n]·h_t[:,n]

    a, b: [B, T, D, N] (a ∈ (0,1]); C: [B, T, N]; h0: [B, D, N].
    Returns (y [B, T, D], h_last [B, D, N]), f32.
    """
    B, T, D, N = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)

    def step(h, xs):
        a_t, b_t, C_t = xs
        h = a_t * h + b_t
        return h, jnp.einsum("bdn,bn->bd", h, C_t)

    xs = (a.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), h_last
