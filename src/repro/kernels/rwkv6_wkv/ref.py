"""Pure-jnp oracle for the RWKV-6 WKV recurrence (sequential scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0=None):
    """r,k,v,w: [B, T, H, n] (w in (0,1)); u: [H, n]; s0: [B, H, n, n].

    y_t = r_t · (S_{t-1} + diag(u)·k_tᵀv_t);  S_t = diag(w_t)·S_{t-1} + k_tᵀv_t
    Returns (y [B,T,H,n], S_final [B,H,n,n]), all f32.
    """
    B, T, H, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, n, n), jnp.float32)

    def step(s, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), s_last
