"""Jit'd public wrapper for the chunked WKV-6 kernel ([B,T,H,n] layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv6_pallas


def wkv6(r, k, v, w, u, s0=None, *, chunk: int = 32,
         interpret: bool | None = None):
    """r,k,v,w: [B,T,H,n]; u: [H,n]; s0: [B,H,n,n].  Returns (y, S_final)
    with y: [B,T,H,n] f32 and S_final: [B,H,n,n] f32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, H, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, n, n), jnp.float32)

    def flat(x):
        return x.swapaxes(1, 2).reshape(B * H, T, n).astype(jnp.float32)

    u_full = jnp.tile(u.astype(jnp.float32), (B, 1))        # [B*H, n]
    y, sfin = wkv6_pallas(flat(r), flat(k), flat(v), flat(w), u_full,
                          s0.reshape(B * H, n, n).astype(jnp.float32),
                          chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, T, n).swapaxes(1, 2)
    return y, sfin.reshape(B, H, n, n)
