"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence.

The sequential scan is O(T) steps of [n,n] outer products — latency-bound on
TPU.  The chunked form processes ``c`` tokens per grid step with dense
[c,·] matrix work (MXU/VPU friendly) while carrying the [n,n] state in VMEM:

  within chunk (inclusive decay products P_t = Π_{τ≤t} w_τ, P as logs):
    y_t = (r_t ⊙ P_{t-1}) · S_chunk_start
        + Σ_{s<t} [Σ_i r_t[i] k_s[i] e^{logP_{t-1}[i] − logP_s[i]}] v_s
        + ((r_t ⊙ u) · k_t) v_t
    S_end = diag(P_c)·S_start + Σ_s diag(P_c/P_s) k_sᵀ v_s

The intra-chunk pairwise term is computed with the exact 3-factor form
(exponent masked to −inf *before* exponentiation), which is numerically
safe for arbitrary decays — no 1/P underflow, every exponent ≤ 0.
Cost per chunk: O(c²·n) VPU + O(c·n²) MXU; VMEM: state n² f32 + O(c²n)
pairwise buffer (c=32, n=64 → 0.3 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sfin_ref,
            s_scr, *, c: int, n: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _load_state():
        s_scr[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)              # [c, n]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)            # [1, n]

    lw = jnp.log(w)                               # ≤ 0
    logP = jnp.cumsum(lw, axis=0)                 # inclusive [c, n]
    logPm1 = logP - lw                            # exclusive (P_{t-1})

    S = s_scr[...]                                # [n, n]
    rt = r * jnp.exp(logPm1)
    y_state = jax.lax.dot_general(rt, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # intra-chunk pairwise term (exact, overflow-free)
    D = logPm1[:, None, :] - logP[None, :, :]     # [c, c, n]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    strict = (si < ti)[:, :, None]
    D = jnp.where(strict, D, NEG_INF)
    A = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(D), axis=2)  # [c, c]
    y_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    diag_term = jnp.sum(r * u * k, axis=1, keepdims=True)            # [c, 1]
    y = y_state + y_intra + diag_term * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S = diag(P_c) S + (k ⊙ e^{logP_c − logP})ᵀ v
    decay_all = jnp.exp(logP[c - 1:c, :])                            # [1, n]
    k2 = k * jnp.exp(logP[c - 1:c, :] - logP)                        # [c, n]
    S_new = decay_all.T * S + jax.lax.dot_general(
        k2, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    @pl.when(j == nj - 1)
    def _emit_state():
        sfin_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, s0, *, chunk: int = 32, interpret: bool = False):
    """r,k,v,w: [BH, T, n] f32; u: [BH, n]; s0: [BH, n, n] -> (y, S_final)."""
    BH, T, n = r.shape
    c = min(chunk, T)
    assert T % c == 0, (T, c)
    grid = (BH, T // c)
    y, sfin = pl.pallas_call(
        functools.partial(_kernel, c=c, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, n), lambda b, j: (b, 0)),
            pl.BlockSpec((1, n, n), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, n, n), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, n), jnp.float32),
            jax.ShapeDtypeStruct((BH, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sfin
