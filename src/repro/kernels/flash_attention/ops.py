"""Jit'd public wrapper: [B, S, H, hd]-layout flash attention with backend
selection (Pallas-TPU on TPU, interpret elsewhere)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    window: int = 0, bq: int = 512, bk: int = 512,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: [B, S, H, hd]; k, v: [B, S, KV, hd]; causal (+ optional window)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(qt, kt, vt, bq=bq, bk=bk, window=window,
                                 interpret=interpret)
    return out.transpose(0, 2, 1, 3)
