"""Pure-jnp oracle for causal (optionally sliding-window) GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, window: int = 0) -> jnp.ndarray:
    """q: [B, S, H, hd]; k, v: [B, S, KV, hd]; causal; window<=0 => full."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = kj <= qi
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
