"""Pallas TPU kernel: causal GQA flash attention (forward).

Online-softmax over KV tiles; the [bq, hd] f32 accumulator and the running
(m, l) statistics live in VMEM scratch across the KV-tile loop, so HBM
traffic is O(S·hd) instead of the O(S²) a materialized score matrix costs —
the memory-roofline win recorded in §Perf.

Grid = (B·H, S/bq, S/bk), KV innermost.  GQA is handled in the k/v index
maps (query head h reads kv head h // (H/KV)); causal + sliding-window tiles
that are fully masked are skipped via ``pl.when`` predication.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, window: int, scale: float):
    i = pl.program_id(1)          # q tile
    j = pl.program_id(2)          # kv tile
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile-level predication: skip fully-masked tiles
    q_first = i * bq                       # first query index in tile
    k_first = j * bk
    causal_live = k_first <= q_first + bq - 1
    window_live = True
    if window > 0:
        window_live = k_first + bk - 1 > q_first - window

    @pl.when(jnp.logical_and(causal_live, window_live))
    def _compute():
        q = q_ref[0]                                   # [bq, hd]
        k = k_ref[0]                                   # [bk, hd]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bk]
        qi = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kj <= qi
        if window > 0:
            mask &= kj > qi - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                            # [bq, 128]
        m_cur = jnp.max(s, axis=1)[:, None]            # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        p = jnp.exp(s - m_new[:, :1])                  # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                 # [bq, 128]
        l_ref[...] = l_ref[...] * corr + jnp.broadcast_to(
            jnp.sum(p, axis=1)[:, None], m_prev.shape)
        acc_ref[...] = acc_ref[...] * corr[:, :1] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "window", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, bq: int = 512, bk: int = 512, window: int = 0,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, S, hd]; k, v: [B, KV, S, hd] -> out [B, H, S, hd]."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    group = H // KV
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0
    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * KV, S, hd)
    vf = v.reshape(B * KV, S, hd)
    grid = (B * H, S // bq, S // bk)
    scale = 1.0 / (hd ** 0.5)

    def kv_index(b, i, j):
        return ((b // H) * KV + (b % H) // group, j, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # m
            pltpu.VMEM((bq, 128), jnp.float32),   # l
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
