"""Kernel autotuning: measured tile/variant selection for the Apriori
hot-loop kernels.

Three pieces:

* :mod:`repro.kernels.autotune.cache` — the persistent winner store,
  keyed ``(kernel, shape-bucket, device kind)``, checked in as
  ``cache.json`` so CI and cold starts get the CI-runner-class winners
  without re-sweeping.  Missing/corrupt caches degrade to the
  roofline-seeded defaults in :mod:`repro.launch.tuning`.
* :mod:`repro.kernels.autotune.tuner` — the sweep: roofline-ordered
  candidates, ``block_until_ready`` + median-of-reps measurement, every
  config verified bit-identical against the jnp oracle before it may win.
* ``CostModelPolicy.from_autotune`` (in :mod:`repro.runtime.policies`)
  consumes :meth:`AutotuneCache.entries_for`, turning measured walls into
  effective peak/bandwidth so the scheduler's roofline estimates come
  from real autotune data instead of constants.
"""
from repro.kernels.autotune.cache import (DEFAULT_CACHE_PATH, AutotuneCache,
                                          default_cache, device_kind,
                                          resolve_config, shape_bucket)
from repro.kernels.autotune.tuner import (TuneResult, tune, tune_into,
                                          standard_shapes)

__all__ = [
    "DEFAULT_CACHE_PATH", "AutotuneCache", "default_cache", "device_kind",
    "resolve_config", "shape_bucket", "TuneResult", "tune", "tune_into",
    "standard_shapes",
]
