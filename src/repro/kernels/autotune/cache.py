"""The autotune winner cache — ``(kernel, shape-bucket, device kind)`` →
measured best config.

Key scheme
----------
``kernel|bucket|device``, e.g. ``support_count|n256_m2048_i128|cpu``:

* *kernel* — ``support_count`` | ``rule_match`` (the tunable hot loops).
* *bucket* — every (padded) call shape rounded up per-dimension to the
  next power of two, so the cache stays O(log) in each axis while the
  planes' pad-to-bucket shape discipline keeps real calls near their
  bucket corner.
* *device* — ``jax.devices()[0].device_kind`` (spaces → ``_``): tile
  winners are a per-silicon property, so a cache tuned on one device
  kind never silently configures another — lookups for an unknown
  device fall through to the roofline-seeded defaults.

Entries store the exact shape they were tuned at, the winning config,
its measured cost, and the full sweep (for audit + the argmin property
test).  ``lookup`` falls back to the *nearest* cached bucket (log-scale
distance, deterministic tie-break) for the same kernel+device before
giving up — a lattice sweep then covers every in-between shape.

Degradation contract: a missing or corrupt cache file loads as an empty
cache (the parse error is kept on ``load_error``, never raised), and an
empty lookup returns ``None`` — callers then use
:func:`repro.launch.tuning.default_config`, the roofline-seeded default.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

DEFAULT_CACHE_PATH = os.path.join(os.path.dirname(__file__), "cache.json")

_DIM_NAMES = {
    "support_count": ("n", "m", "i"),
    "intersect_count": ("m", "w"),
    "rule_match": ("b", "r", "i"),
}


def device_kind() -> str:
    """Canonical device-kind token for cache keys (lazy jax import so the
    cache file itself can be read without a backend)."""
    import jax
    return jax.devices()[0].device_kind.replace(" ", "_")


def _pow2_ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def shape_bucket(kernel: str, shape: Tuple[int, ...]) -> str:
    names = _DIM_NAMES.get(kernel)
    if names is None or len(shape) != len(names):
        raise ValueError(f"unknown kernel/shape: {kernel} {shape}")
    return "_".join(f"{n}{_pow2_ceil(d)}" for n, d in zip(names, shape))


def _bucket_dims(bucket: str) -> List[int]:
    return [int(part[1:]) for part in bucket.split("_")]


@dataclass
class AutotuneCache:
    """In-memory view of one cache file (see module docstring)."""

    entries: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    path: Optional[str] = None
    load_error: Optional[str] = None

    # ------------------------------------------------------------------
    @classmethod
    def load(cls, path: str = DEFAULT_CACHE_PATH) -> "AutotuneCache":
        """Read a cache file; missing/corrupt files load empty, with the
        reason on ``load_error`` — autotuning must never take a plane
        down, it can only make it faster."""
        try:
            with open(path) as f:
                data = json.load(f)
            entries = data["entries"]
            if not isinstance(entries, dict):
                raise TypeError("entries must be an object")
            for key, ent in entries.items():
                if "config" not in ent or "cost_us" not in ent:
                    raise KeyError(f"entry {key!r} missing config/cost_us")
            return cls(entries=dict(entries), path=path)
        except FileNotFoundError as e:
            return cls(path=path, load_error=str(e))
        except (json.JSONDecodeError, KeyError, TypeError, ValueError) as e:
            return cls(path=path, load_error=f"corrupt cache {path}: {e}")

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path or DEFAULT_CACHE_PATH
        payload = {
            "meta": {
                "note": "autotuned kernel configs; key = "
                        "kernel|shape-bucket|device_kind",
                "refresh": "python -m repro.launch.autotune",
            },
            "entries": {k: self.entries[k] for k in sorted(self.entries)},
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        self.path = path
        return path

    # ------------------------------------------------------------------
    @staticmethod
    def key(kernel: str, shape: Tuple[int, ...],
            device: Optional[str] = None) -> str:
        return f"{kernel}|{shape_bucket(kernel, shape)}|" \
               f"{device or device_kind()}"

    def put(self, kernel: str, shape: Tuple[int, ...],
            config: Dict[str, Any], cost_us: float,
            swept: Optional[List[Dict[str, Any]]] = None,
            device: Optional[str] = None) -> str:
        key = self.key(kernel, shape, device)
        self.entries[key] = {
            "shape": [int(d) for d in shape],
            "config": dict(config),
            "cost_us": round(float(cost_us), 3),
            "source": "measured",
            "swept": swept or [],
        }
        return key

    # ------------------------------------------------------------------
    def lookup(self, kernel: str, shape: Tuple[int, ...],
               device: Optional[str] = None) -> Optional[Dict[str, Any]]:
        """Best known entry for this call shape: exact bucket, else the
        nearest cached bucket (same kernel+device) by log2 distance."""
        device = device or device_kind()
        exact = self.entries.get(self.key(kernel, shape, device))
        if exact is not None:
            return exact
        want = _bucket_dims(shape_bucket(kernel, shape))
        prefix, suffix = f"{kernel}|", f"|{device}"
        best_key, best_dist = None, None
        for key in sorted(self.entries):
            if not (key.startswith(prefix) and key.endswith(suffix)):
                continue
            dims = _bucket_dims(key.split("|")[1])
            dist = sum(abs(a.bit_length() - b.bit_length())
                       for a, b in zip(dims, want))
            if best_dist is None or dist < best_dist:
                best_key, best_dist = key, dist
        return self.entries.get(best_key) if best_key else None

    def entries_for(self, kernel: str, device: Optional[str] = None
                    ) -> List[Dict[str, Any]]:
        device = device or device_kind()
        prefix, suffix = f"{kernel}|", f"|{device}"
        return [self.entries[k] for k in sorted(self.entries)
                if k.startswith(prefix) and k.endswith(suffix)]

    def has_kernel(self, kernel: str, device: Optional[str] = None) -> bool:
        return bool(self.entries_for(kernel, device))

    def __len__(self) -> int:
        return len(self.entries)


# ---------------------------------------------------------------------------
# module-level default (the checked-in cache) + the ops-facing resolver
# ---------------------------------------------------------------------------

_default: Optional[AutotuneCache] = None


def default_cache(reload: bool = False) -> AutotuneCache:
    global _default
    if _default is None or reload:
        _default = AutotuneCache.load(DEFAULT_CACHE_PATH)
    return _default


def resolve_config(kernel: str, shape: Tuple[int, ...],
                   tuning: Any = None) -> Dict[str, Any]:
    """The single dispatch point the ops wrappers call per kernel launch.

    ``tuning`` selects the source of the config:
      * ``None``  — the checked-in default cache (autotuning ON);
      * ``False`` — autotuning OFF: the roofline-seeded default config;
      * a ``dict`` — an explicit config (tests / the tuner itself);
      * an :class:`AutotuneCache` — that cache (tuner round-trips, CI
        smoke sweeps writing to a scratch path).

    Cache misses — including cold/corrupt caches and unknown device
    kinds — fall back to :func:`repro.launch.tuning.default_config`.
    """
    from repro.launch.tuning import default_config
    if isinstance(tuning, dict):
        return dict(tuning)
    if tuning is False:
        return default_config(kernel, shape)
    cache = tuning if isinstance(tuning, AutotuneCache) else default_cache()
    entry = cache.lookup(kernel, shape)
    if entry is not None:
        return dict(entry["config"])
    return default_config(kernel, shape)
