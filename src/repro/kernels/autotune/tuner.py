"""The autotune sweep: measure every candidate config, verify it
bit-identical, cache the argmin.

Measurement discipline (the same fix applied to ``bench_kernels``): the
warm-up call is ``block_until_ready``-synced so compile time never leaks
into the first rep, then the config's cost is the **median of >= 3
synced reps** — tile decisions made on one noisy dispatch are how a
tuner ends up *pessimizing* a kernel.

Correctness discipline: a config may only win if its output is exactly
equal to the jnp oracle's (int32 counts / f32 confidence-weighted
scores — both exact, so equality is bit-equality).  Mismatching configs
are recorded (``matched=False``) and excluded from the argmin; the
differential-fuzz harness (`tests/test_kernel_fuzz.py`) holds the whole
candidate space to the same bar.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.autotune.cache import AutotuneCache, device_kind
from repro.kernels.rule_match.fused import rule_scores_fused
from repro.kernels.rule_match.kernel import rule_scores_pallas
from repro.kernels.rule_match.ref import rule_scores_ref
from repro.kernels.support_count.fused import support_count_fused
from repro.kernels.support_count.intersect import intersect_count_pallas
from repro.kernels.support_count.kernel import support_count_pallas
from repro.kernels.support_count.ref import (intersect_count_ref,
                                             support_count_ref)
from repro.launch.tuning import kernel_candidates, seed_order


@dataclass
class SweptConfig:
    config: Dict[str, Any]
    cost_us: float
    matched: bool                     # bit-identical to the oracle


@dataclass
class TuneResult:
    kernel: str
    shape: Tuple[int, ...]
    device: str
    best: Dict[str, Any]
    cost_us: float
    swept: List[SweptConfig] = field(default_factory=list)

    def summary(self) -> str:
        return (f"{self.kernel} {self.shape} [{self.device}]: "
                f"{self.best} @ {self.cost_us:.1f}us "
                f"({len(self.swept)} configs swept)")


# ---------------------------------------------------------------------------
# synthetic inputs + per-kernel runners (kernel entry points, not the ops
# wrappers — the tuner must pin tiles exactly, not re-enter the resolver)
# ---------------------------------------------------------------------------

def make_inputs(kernel: str, shape: Tuple[int, ...], seed: int = 0
                ) -> Dict[str, jnp.ndarray]:
    """Padded synthetic inputs at the sweep shape, density matched to the
    planes (sparse transactions/baskets, 1-4 item candidates/antecedents,
    a tail of never-match padding rows on the serving side)."""
    rng = np.random.default_rng(seed)
    if kernel == "intersect_count":
        # two random packed tid-slabs (every bit pattern is a legal
        # tid-list, so uniform uint32 words exercise the full popcount)
        m, w = shape
        bits = rng.integers(0, 2**32, size=(2, m, w), dtype=np.uint32)
        return {"A": jnp.asarray(bits[0]), "B": jnp.asarray(bits[1])}
    n, m, i = shape
    X = (rng.random((n, i)) < 0.3).astype(np.int8)
    A = np.zeros((m, i), np.int8)
    for r in range(m):
        A[r, rng.choice(i, size=1 + r % 4, replace=False)] = 1
    if kernel == "support_count":
        sizes = A.astype(np.float32).sum(axis=1)[None, :]
        return {"T": jnp.asarray(X), "C": jnp.asarray(A),
                "sizes": jnp.asarray(sizes)}
    # rule_match: last eighth of the rows are index padding (sizes=-1)
    pad_from = m - max(m // 8, 1)
    sizes = A.astype(np.float32).sum(axis=1)
    conf = rng.random(m).astype(np.float32) * 0.9 + 0.1
    A[pad_from:] = 0
    sizes[pad_from:] = -1.0
    conf[pad_from:] = 0.0
    return {"Q": jnp.asarray(X), "A": jnp.asarray(A),
            "sizes": jnp.asarray(sizes[None, :]),
            "conf": jnp.asarray(conf[None, :])}


def run_config(kernel: str, config: Dict[str, Any],
               inputs: Dict[str, jnp.ndarray],
               interpret: bool) -> jnp.ndarray:
    cfg = dict(config)
    variant = cfg.pop("variant")
    if kernel == "intersect_count":
        return intersect_count_pallas(inputs["A"], inputs["B"],
                                      bm=cfg["bm"], bw=cfg["bw"],
                                      interpret=interpret)
    if kernel == "support_count":
        T, C, sizes = inputs["T"], inputs["C"], inputs["sizes"]
        if variant == "packed":
            return support_count_fused(T, C, bn=cfg["bn"], bm=cfg["bm"],
                                       interpret=interpret)
        return support_count_pallas(T, C, sizes, bn=cfg["bn"], bm=cfg["bm"],
                                    bi=cfg["bi"], interpret=interpret)
    Q, A = inputs["Q"], inputs["A"]
    sizes, conf = inputs["sizes"], inputs["conf"]
    if variant == "packed":
        return rule_scores_fused(Q, A, sizes, conf, bb=cfg["bb"],
                                 br=cfg["br"], interpret=interpret)
    return rule_scores_pallas(Q, A, sizes, conf, bb=cfg["bb"], br=cfg["br"],
                              bi=cfg["bi"], interpret=interpret)


def oracle(kernel: str, inputs: Dict[str, jnp.ndarray]) -> np.ndarray:
    if kernel == "intersect_count":
        return np.asarray(intersect_count_ref(inputs["A"], inputs["B"])
                          )[None, :].astype(np.int32)
    if kernel == "support_count":
        return np.asarray(support_count_ref(inputs["T"], inputs["C"])
                          )[None, :].astype(np.int32)
    return np.asarray(rule_scores_ref(inputs["Q"], inputs["A"],
                                      inputs["sizes"][0], inputs["conf"][0]))


# ---------------------------------------------------------------------------
# measurement + the sweep
# ---------------------------------------------------------------------------

def measure_us(fn: Callable[[], Any], reps: int = 3,
               timer: Callable[[], float] = time.perf_counter) -> float:
    """Median wall µs over ``reps`` fully-synced calls (warm-up synced
    too, so compilation never pollutes rep 0)."""
    reps = max(int(reps), 3)
    jax.block_until_ready(fn())                  # compile + warm, synced
    walls = []
    for _ in range(reps):
        t0 = timer()
        jax.block_until_ready(fn())
        walls.append(timer() - t0)
    return float(np.median(walls)) * 1e6


def tune(kernel: str, shape: Tuple[int, ...], *,
         configs: Optional[Sequence[Dict[str, Any]]] = None,
         max_configs: int = 0, reps: int = 3, seed: int = 0,
         interpret: Optional[bool] = None,
         timer: Callable[[], float] = time.perf_counter) -> TuneResult:
    """Sweep one (kernel, shape): returns the measured argmin config.

    ``max_configs > 0`` truncates the roofline-ordered candidate list —
    the CI smoke mode (2 configs per kernel) still measures the configs
    the seed model believes in.  Raises if *no* config reproduces the
    oracle (a correctness bug, not a tuning failure).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cands = list(configs) if configs is not None \
        else seed_order(kernel, shape, kernel_candidates(kernel, shape))
    if max_configs > 0:
        cands = cands[:max_configs]
    inputs = make_inputs(kernel, shape, seed=seed)
    want = oracle(kernel, inputs)

    swept: List[SweptConfig] = []
    for cfg in cands:
        out = np.asarray(run_config(kernel, cfg, inputs, interpret))
        matched = out.shape == want.shape and np.array_equal(out, want)
        cost = measure_us(
            lambda c=cfg: run_config(kernel, c, inputs, interpret),
            reps=reps, timer=timer) if matched else float("inf")
        swept.append(SweptConfig(config=dict(cfg), cost_us=cost,
                                 matched=matched))
    ok = [s for s in swept if s.matched]
    if not ok:
        raise RuntimeError(f"autotune {kernel} {shape}: no candidate "
                           f"matched the oracle ({len(swept)} swept)")
    best = min(ok, key=lambda s: s.cost_us)
    return TuneResult(kernel=kernel, shape=tuple(shape),
                      device=device_kind(), best=best.config,
                      cost_us=best.cost_us, swept=swept)


def standard_shapes(kernel: str, smoke: bool = False
                    ) -> List[Tuple[int, int, int]]:
    """The sweep lattice: one shape per bucket the planes actually hit
    (B6 tiles 64-1024 rows x 128-2048 candidates; B7 buckets 1-64
    queries x 128-512 index rows), nearest-bucket lookup covers the
    rest.  ``smoke`` shrinks to one tiny shape for the CI sweep leg."""
    if kernel == "support_count":
        if smoke:
            return [(64, 128, 128)]
        return [(n, m, 128) for n in (64, 256, 1024)
                for m in (128, 256, 512, 2048)]
    if kernel == "intersect_count":
        # Eclat rounds: candidate count varies widely, word axis is
        # W = ceil(n_tx/32) padded to 128 lanes (128 words ≈ 4k tx)
        if smoke:
            return [(128, 128)]
        return [(m, w) for m in (128, 512, 2048) for w in (128, 256)]
    if smoke:
        return [(8, 128, 128)]
    return [(b, r, 128) for b in (8, 64) for r in (128, 512)]


def tune_into(cache: AutotuneCache, kernel: str,
              shapes: Optional[Sequence[Tuple[int, ...]]] = None,
              log: Optional[Callable[[str], None]] = None,
              **tune_kwargs) -> List[TuneResult]:
    """Sweep a shape list into a cache (entries keyed per shape bucket)."""
    results = []
    for shape in shapes if shapes is not None else standard_shapes(kernel):
        res = tune(kernel, shape, **tune_kwargs)
        cache.put(kernel, res.shape, res.best, res.cost_us,
                  swept=[{"config": s.config, "cost_us":
                          (None if s.cost_us == float("inf")
                           else round(s.cost_us, 3)),
                          "matched": s.matched} for s in res.swept],
                  device=res.device)
        if log:
            log(res.summary())
        results.append(res)
    return results
