"""The MiningBackend protocol + algorithm resolution.

Every mining backend is an object with the same ``run`` signature as
:class:`repro.pipeline.MarketBasketPipeline` and returns the same
:class:`repro.pipeline.PipelineResult` — frequent itemsets, supports,
rules and a report — pinned bit-identical across backends by the parity
tests and the CLI ``--smoke`` paths.  Callers pick one with
``PipelineConfig.algorithm``:

* ``apriori`` — horizontal bitmap rounds (:class:`MarketBasketPipeline`);
* ``eclat``   — vertical tid-list intersections (:class:`EclatMiner`);
* ``auto``    — :func:`repro.mining.select.select_algorithm` prices both
  formulations on the dataset's measured density features and picks one
  (the decision travels back as an :class:`AlgorithmChoice`).
"""
from __future__ import annotations

from typing import List, Optional, Protocol, Tuple, Union

from repro.core.hetero import HeterogeneityProfile
from repro.core.mapreduce import FailureEvent
from repro.mining.eclat.miner import EclatMiner
from repro.mining.select import (AlgorithmChoice, AlgorithmCostModel,
                                 select_algorithm)
from repro.pipeline.pipeline import (Baskets, MarketBasketPipeline,
                                     PipelineConfig, PipelineResult)
from repro.runtime import SwitchingPolicy

ALGORITHMS = ("apriori", "eclat", "auto")


class MiningBackend(Protocol):
    """What every mining plane exposes (structural — no registration)."""

    config: PipelineConfig

    def run(self, baskets: Baskets,
            failures: Optional[List[FailureEvent]] = None) -> PipelineResult:
        ...


def resolve_algorithm(algorithm: str) -> str:
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown mining algorithm {algorithm!r} "
                         f"(known: {', '.join(ALGORITHMS)})")
    return algorithm


def make_miner(baskets: Baskets,
               profile: Optional[HeterogeneityProfile] = None,
               config: Optional[PipelineConfig] = None,
               policy: Union[str, SwitchingPolicy, None] = None,
               model: Optional[AlgorithmCostModel] = None,
               son=None,
               ) -> Tuple[MiningBackend, Optional[AlgorithmChoice]]:
    """Resolve ``config.algorithm`` to a ready miner.

    ``auto`` measures the dataset (density stats come straight from the
    slab/bitmap/id-lists, no densification) and routes through the
    algorithm cost model — seeded from the autotune cache's measured
    walls, roofline on a cold cache; the returned
    :class:`AlgorithmChoice` carries the full evidence trail (``None``
    when the algorithm was explicit).  ``model`` lets tests script the
    rates.

    ``son`` (a :class:`repro.mining.son.SONConfig`) routes to the
    out-of-core two-pass :class:`repro.mining.son.SONMiner` instead — the
    algorithm (including ``auto``, re-priced on the partition-sized
    problem) resolves per run inside the miner, so the choice is returned
    as ``None`` here and surfaced as ``miner.algorithm_choice`` after
    ``run()``.
    """
    config = config or PipelineConfig()
    algorithm = resolve_algorithm(config.algorithm)
    if son is not None:
        from repro.mining.son import SONMiner
        return SONMiner(profile=profile, config=config, son=son,
                        policy=policy), None
    choice: Optional[AlgorithmChoice] = None
    if algorithm == "auto":
        # min_support resolves against the true tx count in every input
        # form; density_stats measures it without densifying
        from repro.data.sparse import density_stats
        stats = density_stats(baskets)
        choice = select_algorithm(baskets, config.abs_support(stats.n_tx),
                                  model=model, stats=stats)
        algorithm = choice.algorithm
    cls = EclatMiner if algorithm == "eclat" else MarketBasketPipeline
    return cls(profile=profile, config=config, policy=policy), choice
