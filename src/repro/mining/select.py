"""Algorithm auto-selection — ``CostModelPolicy`` extended with measured
density/sparsity features.

The paper's pitch is heterogeneous cores running *the right work*; the
survey line (Singh et al.) adds that the right work is also the right
*formulation*: Apriori's horizontal bitmap pays O(n_tx × n_items) per
candidate level regardless of density, the vertical (Eclat) formulation
pays O(candidates × n_tx/32) words after a one-time columnization.
Which wins depends on the dataset, so ``auto`` prices both formulations'
dominant k=2 round on the measured :class:`repro.data.sparse.DensityStats`
and picks the cheaper one.

Rate seeding follows the same ladder as the switching policies: per
kernel, effective peak/bandwidth come from the autotune cache's measured
walls (``CostModelPolicy.from_autotune``); a cold/corrupt/other-device
cache degrades that kernel to the datasheet roofline constants — never
raises (the degradation contract the autotune plane guarantees).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.data.sparse import BasketsLike, DensityStats, density_stats
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.launch.tuning import shape_flops_bytes
from repro.runtime.policies import CostModelPolicy

WORD_BITS = 32

# the kernel each formulation's map rounds dispatch to — the rates that
# decide the algorithm must be the rates the chosen plan will then run at
ALGORITHM_KERNELS = {"apriori": "support_count", "eclat": "intersect_count"}


def _pad_up(n: int, multiple: int = 128) -> int:
    return max(n, 1) + (-max(n, 1)) % multiple


@dataclass(frozen=True)
class AlgorithmChoice:
    """One auto-selection decision, with its full evidence trail."""

    algorithm: str                       # "apriori" | "eclat"
    est_cost_s: Dict[str, float]         # per-algorithm modeled seconds
    features: Dict[str, float]           # density stats + derived counts
    cost_source: Dict[str, str]          # per-kernel: "autotune"|"roofline"

    def summary(self) -> str:
        costs = ", ".join(f"{a}={s:.2e}s" for a, s in
                          sorted(self.est_cost_s.items()))
        src = ", ".join(f"{k}:{v}" for k, v in sorted(self.cost_source.items()))
        return (f"auto-selected {self.algorithm} ({costs}; "
                f"density={self.features['density']:.4f}, "
                f"f1={int(self.features['n_frequent_items'])}; rates {src})")


class AlgorithmCostModel:
    """Per-kernel effective (peak, bw) rates + the formulation cost model.

    ``kernel_rates`` maps kernel name → ``(peak_flops, hbm_bw)``; tests
    inject scripted rates here to pin the decision logic.  Absent kernels
    price at the datasheet roofline constants.
    """

    def __init__(self, kernel_rates: Optional[Dict[str, Tuple[float, float]]]
                 = None,
                 cost_source: Optional[Dict[str, str]] = None):
        self.kernel_rates = dict(kernel_rates or {})
        self.cost_source = dict(cost_source or {})

    @classmethod
    def from_autotune(cls, cache=None) -> "AlgorithmCostModel":
        """Seed every formulation's kernel from its measured cache walls;
        per-kernel roofline fallback on a cold cache (never raises)."""
        from repro.kernels.autotune.cache import default_cache
        cache = cache if cache is not None else default_cache()
        rates: Dict[str, Tuple[float, float]] = {}
        source: Dict[str, str] = {}
        for kernel in set(ALGORITHM_KERNELS.values()):
            try:
                pol = CostModelPolicy.from_autotune(cache, kernel)
                rates[kernel] = (pol.peak_flops, pol.hbm_bw)
                source[kernel] = pol.cost_source          # "autotune"
            except ValueError:
                source[kernel] = "roofline"
        return cls(kernel_rates=rates, cost_source=source)

    # ------------------------------------------------------------------
    def _seconds(self, kernel: str, shape: Tuple[int, ...]) -> float:
        peak, bw = self.kernel_rates.get(kernel, (PEAK_FLOPS, HBM_BW))
        flops, bytes_ = shape_flops_bytes(kernel, shape)
        return max(flops / peak, bytes_ / bw)

    def estimate(self, stats: DensityStats,
                 min_sup_abs: int) -> AlgorithmChoice:
        """Price both formulations' dominant work on measured features.

        The k=1 pass is format-native for both; the fork is the k=2 round
        (almost always the widest candidate level): Apriori counts
        f1·(f1−1)/2 pair candidates against the full padded bitmap, Eclat
        pays a one-time columnization then intersects the same pairs as
        packed tid words.  f1 comes from the *measured* per-item counts —
        not an independence guess — so a dataset whose wide universe is
        mostly infrequent (the sparse regime) prices tiny for both, and
        the dense regime's kernel-rate gap decides."""
        f1 = int((stats.item_counts >= min_sup_abs).sum())
        m2 = f1 * (f1 - 1) // 2
        n_pad = _pad_up(stats.n_tx, 8)
        i_pad = _pad_up(stats.n_items, 128)
        m2_pad = _pad_up(m2, 128)
        w_pad = _pad_up((stats.n_tx + WORD_BITS - 1) // WORD_BITS, 128)

        apriori_s = self._seconds("support_count", (n_pad, m2_pad, i_pad))
        # columnize: one pass over the nnz cells plus the packed slab write,
        # priced at the intersect kernel's effective bandwidth
        _, bw = self.kernel_rates.get("intersect_count", (PEAK_FLOPS, HBM_BW))
        columnize_s = (4.0 * stats.nnz + 4.0 * i_pad * w_pad) / bw
        eclat_s = columnize_s + self._seconds("intersect_count",
                                              (m2_pad, w_pad))
        costs = {"apriori": apriori_s, "eclat": eclat_s}
        pick = min(costs, key=lambda a: (costs[a], a))
        return AlgorithmChoice(
            algorithm=pick, est_cost_s=costs,
            features={"n_tx": float(stats.n_tx),
                      "n_items": float(stats.n_items),
                      "nnz": float(stats.nnz),
                      "density": float(stats.density),
                      "max_item_frequency": float(stats.max_item_frequency),
                      "n_frequent_items": float(f1),
                      "n_pair_candidates": float(m2)},
            cost_source={k: self.cost_source.get(k, "roofline")
                         for k in set(ALGORITHM_KERNELS.values())})


def select_algorithm(baskets: BasketsLike, min_sup_abs: int,
                     model: Optional[AlgorithmCostModel] = None,
                     stats: Optional[DensityStats] = None) -> AlgorithmChoice:
    """Measure the dataset's density features and pick a formulation."""
    if stats is None:
        stats = density_stats(baskets)
    model = model or AlgorithmCostModel.from_autotune()
    return model.estimate(stats, min_sup_abs)


# ---------------------------------------------------------------------------
# SON out-of-core partition scaling
# ---------------------------------------------------------------------------

def local_min_support(min_sup_abs: int, partition_rows: int, n_tx: int) -> int:
    """SON's per-partition absolute threshold: ``floor(G * p / n)``, clamped
    to >= 1.  The *floor* is load-bearing: if an itemset misses this bound
    in every partition, its global count is strictly below
    ``sum_p floor(G * p_rows / n) <= G`` — so no globally frequent itemset
    can be absent from every local result (SON's no-false-negative
    guarantee, the property the bit-identity tests pin)."""
    if n_tx <= 0:
        return 1
    return max(1, (min_sup_abs * partition_rows) // n_tx)


def partition_stats(stats: DensityStats, partition_rows: int) -> DensityStats:
    """Corpus-level density stats scaled down to one SON partition.

    Item frequencies scale ~linearly with rows for the synthetic and retail
    corpora in tree (items are iid across transactions), so the partition's
    feature vector is the corpus's at ``partition_rows / n_tx``.  Using the
    same scaled stats for *every* partition keeps the auto-selection a
    single global decision — one formulation, one jit-cache family, and a
    resume that cannot flip algorithms mid-mine."""
    rows = max(1, min(int(partition_rows), stats.n_tx or 1))
    frac = rows / stats.n_tx if stats.n_tx else 0.0
    counts = np.floor(stats.item_counts.astype(np.float64) * frac
                      ).astype(np.int64)
    nnz = int(counts.sum())
    cells = rows * stats.n_items
    return DensityStats(
        n_tx=rows, n_items=stats.n_items, nnz=nnz,
        density=nnz / cells if cells else 0.0,
        item_counts=counts,
        max_item_frequency=(float(counts.max()) / rows
                            if rows and stats.n_items else 0.0))


def select_partition_algorithm(stats: DensityStats, partition_rows: int,
                               min_sup_abs: int,
                               model: Optional[AlgorithmCostModel] = None
                               ) -> AlgorithmChoice:
    """Auto-selection for the SON plane: price both formulations on the
    *partition-sized* problem (that is where the map rounds actually run)
    at the partition-scaled local threshold, and pick once for all
    partitions."""
    ps = partition_stats(stats, partition_rows)
    model = model or AlgorithmCostModel.from_autotune()
    return model.estimate(ps, local_min_support(min_sup_abs, ps.n_tx,
                                                stats.n_tx))
