"""SON out-of-core two-pass mining with crash-safe checkpointed resume.

The paper's Hadoop framing is disk-backed: Map/Reduce over HDFS partitions,
with the Job Tracker reassigning work when a Task Tracker dies.  The in-tree
planes all hold the corpus in (device) memory; this module adds the standard
answer from the Singh et al. MapReduce-frequent-itemset survey (arXiv
1702.06284) — partitioned two-pass SON (Savasere–Omiecinski–Navathe):

  pass 0 (spill):  slice the corpus into disk-resident CSR chunks of
                   ``partition_rows`` transactions (checkpoint/store is the
                   spill format — one step per partition);
  pass 1 (local):  mine each chunk independently through the existing
                   MiningBackend planes (MarketBasketPipeline / EclatMiner,
                   or a per-partition ShardedMiner when a mesh is given) at
                   the scaled threshold ``floor(G * p_rows / n_tx)``; the
                   union of local winners is a superset of the global
                   frequent set (no false negatives — see
                   :func:`repro.mining.select.local_min_support`);
  pass 2 (count):  re-count the whole union against every chunk, streamed
                   chunk by chunk through the fused ``support_count`` data
                   plane, then filter at the true global threshold.

Because pass 2 counts exactly and the union can only over-approximate, the
surviving ``supports`` dict equals the single-shot pipeline's bit for bit,
and ``generate_rules`` sorts on a total order — so rules match too (pinned
by tests/test_son.py across dense/sparse x apriori/eclat x static/dynamic).

Every partition boundary writes a ``son_state`` checkpoint (completed-
partition bitmaps, the candidate union as per-level id matrices, partial
global counts) through :mod:`repro.checkpoint.store` with ``keep_last``
retention; a killed job restarts from the last completed partition and
finishes bit-identical to an uninterrupted run.  The candidate order is
*recomputed* canonically (sorted by level, then lexicographically) rather
than stored, so a resumed pass 2 indexes its counts identically by
construction.  ``FaultPlan`` events routed to a partition trigger the
existing shard re-plan inside that partition's local pass.

All phases — spill writes, chunk loads, local-pass sub-phases (absorbed
with a ``son-p<i>/`` prefix), re-count map rounds, checkpoint writes, rule
extraction — are priced through the shared :class:`repro.runtime.Runtime`
ledger like every other plane.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

import jax.numpy as jnp

from repro.checkpoint import store
from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import AprioriResult, itemsets_to_bitmap
from repro.core.mapreduce import MapReduceJob, SimulatedCluster
from repro.core.power import PowerModel
from repro.core.rules import generate_rules
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.data.baskets import pad_items
from repro.data.sparse import DensityStats, SparseSlab, density_stats
from repro.mining.select import (AlgorithmChoice, local_min_support,
                                 select_partition_algorithm)
from repro.pipeline.dataplane import DataPlane, uniform_tiles
from repro.pipeline.pipeline import (Baskets, PipelineConfig, PipelineResult,
                                     support_flops)
from repro.pipeline.report import PipelineReport
from repro.runtime import (MeasuredPhase, Runtime, SlabPool, SwitchingPolicy,
                           autotuned_costmodel, donated_add)

_META_FILE = "corpus.json"


class SONKilled(RuntimeError):
    """Raised by the ``abort_after`` test hook after N completed partition
    boundaries — the state on disk is exactly a mid-job kill's."""

    def __init__(self, boundary: int):
        super().__init__(f"SON mine aborted after partition boundary "
                         f"{boundary} (checkpoint saved)")
        self.boundary = boundary


@dataclass(frozen=True)
class SONConfig:
    """Out-of-core knobs, separate from :class:`PipelineConfig` (which keeps
    describing *what* to mine; this describes how to stage it on disk)."""

    workdir: str                  # spill chunks + son_state checkpoints
    partition_rows: int = 4096    # transactions per disk-resident chunk
    resume: bool = False          # restart from the last completed boundary
    keep_last: int = 2            # boundary-checkpoint retention
    codec: Optional[str] = None   # checkpoint/spill codec (None = best)
    # test hook: raise SONKilled once this many partition boundaries have
    # committed their checkpoint — the kill-at-every-boundary resume tests
    # and the CI kill-and-resume smoke drive it
    abort_after: Optional[int] = None

    def __post_init__(self):
        if not self.workdir:
            raise ValueError("SONConfig.workdir is required (spill target)")
        if self.partition_rows < 1:
            raise ValueError(
                f"partition_rows must be >= 1, got {self.partition_rows}")


def partition_slices(n_tx: int, partition_rows: int) -> List[Tuple[int, int]]:
    """Row ranges [lo, hi) of each disk chunk (last one may be short)."""
    return [(lo, min(lo + partition_rows, n_tx))
            for lo in range(0, max(n_tx, 1), partition_rows)]


def _slice_slab(baskets: Baskets, lo: int, hi: int, n_items: int) -> SparseSlab:
    """Rows [lo, hi) of any accepted input form, as a CSR chunk."""
    if isinstance(baskets, SparseSlab):
        base = int(baskets.indptr[lo])
        indptr = (baskets.indptr[lo:hi + 1] - base).astype(np.int64)
        indices = baskets.indices[base:int(baskets.indptr[hi])]
        return SparseSlab(indptr=indptr, indices=np.ascontiguousarray(indices),
                          n_items=baskets.n_items)
    if isinstance(baskets, np.ndarray):
        return SparseSlab.from_dense(baskets[lo:hi])
    return SparseSlab.from_baskets(list(baskets)[lo:hi], n_items=n_items)


def corpus_fingerprint(stats: DensityStats, cfg: PipelineConfig,
                       partition_rows: int) -> str:
    """Identity of (corpus, mining problem, partitioning) — a resumed run
    must match it exactly, or its checkpoints describe a different job."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(stats.item_counts).tobytes())
    h.update(repr((stats.n_tx, stats.n_items, stats.nnz, int(partition_rows),
                   cfg.abs_support(stats.n_tx), cfg.min_confidence,
                   cfg.min_lift, cfg.max_k, cfg.algorithm)).encode())
    return h.hexdigest()[:16]


class SONMiner:
    """Two-pass out-of-core mining behind the :class:`MiningBackend`
    protocol — same ``run(baskets, faults)`` shape, same
    :class:`PipelineResult`, bit-identical supports and rules.

    ``faults`` maps partition index → the fault argument of the local plane
    (a :class:`FaultPlan` when a ``mesh`` makes the local pass sharded, a
    list of :class:`FailureEvent` for the simulated planes) — device loss
    mid-partition re-plans *inside* that partition, surfaced as
    ``report.replans``.
    """

    def __init__(self, profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[PipelineConfig] = None,
                 son: Optional[SONConfig] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None,
                 policy: "SwitchingPolicy | str | None" = None,
                 mesh=None, row_block: int = 8):
        if son is None:
            raise ValueError("SONMiner requires a SONConfig (workdir, "
                             "partition_rows)")
        self.son = son
        self.profile = profile or HeterogeneityProfile.paper()
        self.config = config or PipelineConfig()
        cfg = self.config
        # sub-miners resolve their own policy from this (a shared resolved
        # DynamicPolicy instance would leak EWMA state across planes)
        self._policy_arg = policy if policy is not None else cfg.policy
        policy = self._policy_arg
        if policy == "costmodel" and cfg.autotune:
            policy = autotuned_costmodel("support_count")
        self.runtime = Runtime(
            self.profile, policy=policy, split=cfg.split,
            power=power if power is not None else cfg.power,
            scheduler=scheduler)
        self.scheduler = self.runtime.scheduler
        self.power = self.runtime.power
        self.cluster = SimulatedCluster(self.profile, self.scheduler,
                                        power=None)  # ledger prices energy
        self.data_plane = DataPlane(cfg.data_plane, m_bucket=cfg.m_bucket,
                                    interpret=cfg.interpret,
                                    tuning=None if cfg.autotune else False,
                                    meter=self.runtime.meter)
        self.slabs = SlabPool()
        self.mesh = mesh
        self.row_block = row_block
        self.algorithm_choice: Optional[AlgorithmChoice] = None
        # local-pass backends keyed by (rows, local_abs_support): at most
        # two distinct keys per corpus (full + ragged last partition), so
        # jit/shard caches are built once, not once per partition
        self._locals: Dict[Tuple[int, int], Any] = {}

    # ------------------------------------------------------------------
    # workdir layout
    # ------------------------------------------------------------------
    @property
    def _spill_dir(self) -> str:
        return os.path.join(self.son.workdir, "spill")

    @property
    def _state_dir(self) -> str:
        return os.path.join(self.son.workdir, "state")

    @property
    def _meta_path(self) -> str:
        return os.path.join(self.son.workdir, _META_FILE)

    # ------------------------------------------------------------------
    # local pass plumbing
    # ------------------------------------------------------------------
    def _local_backend(self, rows: int, local_abs: int, algorithm: str):
        key = (rows, local_abs)
        backend = self._locals.get(key)
        if backend is None:
            # abs_support treats min_support <= 1.0 as a fraction, so an
            # absolute threshold of 1 is encoded as fraction 0.0 (which
            # abs_support clamps back up to 1)
            ms = float(local_abs) if local_abs > 1 else 0.0
            lcfg = dataclasses.replace(self.config, algorithm=algorithm,
                                       min_support=ms)
            if self.mesh is not None:
                from repro.distributed.mining import partition_miner
                backend = partition_miner(mesh=self.mesh, config=lcfg,
                                          base_profile=self.profile,
                                          policy=self._policy_arg,
                                          row_block=self.row_block)
            else:
                from repro.mining.backend import make_miner
                backend, _ = make_miner(None, profile=self.profile,
                                        config=lcfg,
                                        policy=self._policy_arg)
            self._locals[key] = backend
        return backend

    def _absorb_ledger(self, p: int, sub_report: PipelineReport) -> None:
        """Fold a local pass's phase records into SON's ledger, prefixed by
        partition — one time/energy axis across the whole mine."""
        if sub_report.ledger is None:
            return
        for rec in sub_report.ledger.phases:
            rec.name = f"son-p{p}/{rec.name}"
            self.runtime.ledger.add(rec)

    # ------------------------------------------------------------------
    # spill + chunk I/O (priced serial phases)
    # ------------------------------------------------------------------
    def _spill_partition(self, p: int, chunk: SparseSlab) -> None:
        nbytes = chunk.indptr.nbytes + chunk.indices.nbytes

        def write():
            store.save(self._spill_dir, p,
                       {"indptr": chunk.indptr, "indices": chunk.indices},
                       extra={"n_items": chunk.n_items, "rows": chunk.n_tx},
                       codec=self.son.codec)

        self.runtime.run_serial(f"son-spill-p{p}", cost=float(max(1, nbytes)),
                                fn=write)

    def _load_partition(self, p: int, cost_est: float) -> SparseSlab:
        def load():
            flat, extra = store.load_arrays(self._spill_dir, p)
            return SparseSlab(indptr=flat["indptr"].astype(np.int64),
                              indices=flat["indices"].astype(np.int32),
                              n_items=int(extra["n_items"]))

        slab, _ = self.runtime.run_serial(f"son-load-p{p}",
                                          cost=float(max(1.0, cost_est)),
                                          fn=load)
        return slab

    # ------------------------------------------------------------------
    # boundary checkpoints
    # ------------------------------------------------------------------
    def _checkpoint(self, boundary: int, p1: np.ndarray, p2: np.ndarray,
                    union: Dict[int, Set[tuple]],
                    counts: Optional[np.ndarray], extra: Dict,
                    report: PipelineReport) -> None:
        tree: Dict[str, np.ndarray] = {"pass1_done": p1, "pass2_done": p2}
        for k in sorted(union):
            tree[f"cand_k{k}"] = np.array(sorted(union[k]),
                                          dtype=np.int32).reshape(-1, k)
        if counts is not None:
            tree["counts"] = counts
        nbytes = sum(int(a.nbytes) for a in tree.values())

        def write():
            store.save(self._state_dir, boundary, tree,
                       extra=dict(extra, boundary=boundary),
                       codec=self.son.codec, keep_last=self.son.keep_last)

        self.runtime.run_serial(f"son-ckpt-b{boundary}",
                                cost=float(max(1, nbytes)), fn=write)
        report.checkpoint_saves += 1
        report.checkpoint_bytes += nbytes
        if (self.son.abort_after is not None
                and boundary >= self.son.abort_after):
            raise SONKilled(boundary)

    def _restore_state(self, P: int, fingerprint: str):
        """(pass1_done, pass2_done, union, counts, algorithm) from the last
        committed boundary, or fresh zeros when the state store is empty."""
        p1 = np.zeros(P, dtype=np.uint8)
        p2 = np.zeros(P, dtype=np.uint8)
        union: Dict[int, Set[tuple]] = {}
        counts: Optional[np.ndarray] = None
        boundary = 0
        algorithm = None
        step = store.latest_step(self._state_dir)
        if step is not None:
            flat, extra = store.load_arrays(self._state_dir, step)
            if extra.get("fingerprint") != fingerprint:
                raise ValueError(
                    "resume rejected: son_state checkpoint was written for "
                    f"a different job (fingerprint {extra.get('fingerprint')}"
                    f" != {fingerprint}) — corpus, thresholds and "
                    "partitioning must match the original run")
            p1 = flat["pass1_done"].astype(np.uint8)
            p2 = flat["pass2_done"].astype(np.uint8)
            for key, arr in flat.items():
                if key.startswith("cand_k"):
                    k = int(key[len("cand_k"):])
                    union[k] = {tuple(int(x) for x in row) for row in arr}
            if "counts" in flat:
                counts = flat["counts"].astype(np.int64)
            boundary = int(extra["boundary"])
            algorithm = extra.get("algorithm")
        return p1, p2, union, counts, boundary, algorithm

    # ------------------------------------------------------------------
    # pass 2: streamed global re-count of one chunk
    # ------------------------------------------------------------------
    def _recount_chunk(self, p: int, slab: SparseSlab, M: int,
                       m_padded: int) -> np.ndarray:
        rt = self.runtime
        T_p = pad_items(slab.to_dense())
        tiles = [rt.meter.h2d(t) for t in uniform_tiles(T_p,
                                                        self.config.n_tiles)]
        tile_rows = np.array([t.shape[0] for t in tiles], dtype=np.float64)
        job = MapReduceJob(
            name=f"son-recount-p{p}",
            map_fn=self.data_plane.tile_counts_device,
            combine_fn=donated_add,
            zero_fn=lambda m=m_padded: self.slabs.take((m,), jnp.int32))

        def finalize(acc):
            host = rt.meter.d2h(acc, dtype=np.int64)[:M]  # chunk's one sync
            self.slabs.give(acc)
            return host

        tile_costs = np.array([job.tile_cost(t) for t in tiles],
                              dtype=np.float64)
        # one family across chunks: every re-count phase has the same tile
        # geometry, so dynamic switching carries speed feedback chunk to
        # chunk exactly like the in-core rounds do
        task = TaskSpec(job.name, float(tile_costs.sum()), parallel=True,
                        n_tiles=len(tiles), family="son-recount")

        def execute(asg, _costs):
            result, rep = self.cluster.run(job, tiles, failures=None,
                                           speculate=self.config.speculate,
                                           assignment=asg)
            return MeasuredPhase(result=finalize(result), busy_s=rep.busy_s,
                                 makespan=rep.makespan,
                                 switches=rep.switches,
                                 reissued=rep.reissued,
                                 failed_devices=list(rep.failed_devices),
                                 tiles_done=rep.tiles_done)

        chunk_counts, _ = rt.run_phase(
            task, execute, tile_costs=tile_costs,
            tile_flops=support_flops(tile_rows, T_p.shape[1], m_padded))
        return chunk_counts

    # ------------------------------------------------------------------
    def run(self, baskets: Baskets,
            faults: Optional[Dict[int, Any]] = None) -> PipelineResult:
        cfg, son, rt = self.config, self.son, self.runtime
        t_start = time.perf_counter()
        rt.ledger.take_since(0)     # drop orphans from a raised prior run
        mark = rt.ledger.mark()
        faults = faults or {}

        stats = density_stats(baskets)
        n_tx, n_items = stats.n_tx, stats.n_items
        min_sup = cfg.abs_support(n_tx)
        parts = partition_slices(n_tx, son.partition_rows)
        P = len(parts)
        fingerprint = corpus_fingerprint(stats, cfg, son.partition_rows)
        # mean chunk size — the deterministic I/O cost estimate for loads
        chunk_cost = (son.partition_rows * 8.0
                      + (stats.nnz / max(n_tx, 1)) * son.partition_rows * 4.0)

        # ---- algorithm: one global decision for every partition --------
        self.algorithm_choice = None
        algorithm = cfg.algorithm
        if algorithm == "auto":
            self.algorithm_choice = select_partition_algorithm(
                stats, son.partition_rows, min_sup)
            algorithm = self.algorithm_choice.algorithm

        # ---- pass 0: spill (fresh) / validate the workdir (resume) -----
        if son.resume:
            if not os.path.exists(self._meta_path):
                raise FileNotFoundError(
                    f"nothing to resume under {son.workdir}: no completed "
                    "spill (corpus.json missing) — rerun without resume")
            with open(self._meta_path) as f:
                meta = json.load(f)
            if meta.get("fingerprint") != fingerprint:
                raise ValueError(
                    "resume rejected: spilled corpus fingerprint "
                    f"{meta.get('fingerprint')} != {fingerprint} — the "
                    "workdir holds a different job")
        else:
            os.makedirs(son.workdir, exist_ok=True)
            for d in (self._spill_dir, self._state_dir):
                if os.path.exists(d):
                    shutil.rmtree(d)
            if os.path.exists(self._meta_path):
                os.remove(self._meta_path)
            for p, (lo, hi) in enumerate(parts):
                self._spill_partition(p, _slice_slab(baskets, lo, hi,
                                                     n_items))
            # written only once every chunk is durable: its presence is the
            # resume path's spill-complete marker
            with open(self._meta_path, "w") as f:
                json.dump({"fingerprint": fingerprint, "n_partitions": P,
                           "partition_rows": son.partition_rows,
                           "algorithm": algorithm}, f)

        # ---- restore (or initialize) the boundary state ----------------
        p1, p2, union, counts, boundary, ckpt_algo = self._restore_state(
            P, fingerprint)
        if ckpt_algo is not None:
            algorithm = ckpt_algo    # a resumed auto decision never flips
        resumed = int(p1.sum() + p2.sum()) if son.resume else 0

        report = PipelineReport(
            backend=self.data_plane.backend, policy=rt.policy.name,
            split=rt.split,
            profile_speeds=[float(s) for s in self.profile.speeds],
            n_tx=n_tx, n_items=n_items, n_tiles=cfg.n_tiles,
            min_support=min_sup, algorithm=algorithm,
            execution="out_of_core", n_partitions=P,
            partition_rows=son.partition_rows, partitions_resumed=resumed)
        ckpt_extra = {"fingerprint": fingerprint, "algorithm": algorithm,
                      "min_sup": min_sup, "n_partitions": P}

        # ---- pass 1: local frequent itemsets per partition --------------
        for p, (lo, hi) in enumerate(parts):
            if p1[p]:
                continue
            rows = hi - lo
            chunk = self._load_partition(p, chunk_cost)
            local_abs = local_min_support(min_sup, rows, n_tx)
            backend = self._local_backend(rows, local_abs, algorithm)
            local = backend.run(chunk, faults.get(p))
            self._absorb_ledger(p, local.report)
            report.replans += local.report.replans
            for itemset in local.supports:
                union.setdefault(len(itemset), set()).add(itemset)
            p1[p] = 1
            boundary += 1
            self._checkpoint(boundary, p1, p2, union, counts, ckpt_extra,
                             report)

        # ---- canonical global candidate order ---------------------------
        # recomputed (never stored): sorted by level then lexicographically,
        # so a resumed pass 2 aligns its restored counts by construction
        cand_list = [t for k in sorted(union) for t in sorted(union[k])]
        M = len(cand_list)
        if counts is None:
            counts = np.zeros(M, dtype=np.int64)

        # ---- pass 2: stream every chunk through the global re-count -----
        if M and not p2.all():
            ni_pad = n_items + (-n_items) % 128
            self.data_plane.prepare(itemsets_to_bitmap(cand_list, ni_pad))
            m_padded = self.data_plane.m_padded
            for p in range(P):
                if p2[p]:
                    continue
                slab = self._load_partition(p, chunk_cost)
                counts = counts + self._recount_chunk(p, slab, M, m_padded)
                p2[p] = 1
                boundary += 1
                self._checkpoint(boundary, p1, p2, union, counts, ckpt_extra,
                                 report)

        # ---- filter at the true global threshold + rules ----------------
        supports: Dict[Tuple[int, ...], int] = {}
        for c, s in zip(cand_list, counts):
            if s >= min_sup:
                supports[c] = int(s)
        levels = max((len(c) for c in supports), default=1)
        rules, rules_rec = rt.run_serial(
            "mba-rules",
            cost=max(1.0, len(supports) * cfg.serial_unit_cost),
            fn=lambda: generate_rules(
                AprioriResult(supports=supports, n_tx=n_tx, levels=levels),
                cfg.min_confidence, min_lift=cfg.min_lift),
            min_speed=cfg.serial_min_speed)
        report.rules_phase = rules_rec

        report.n_itemsets = len(supports)
        report.n_rules = len(rules)
        report.wall_time_s = time.perf_counter() - t_start
        report.ledger = rt.ledger.take_since(mark)
        return PipelineResult(supports=supports, rules=rules, report=report,
                              n_tx=n_tx)
