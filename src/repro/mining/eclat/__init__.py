"""Eclat vertical-mining plane (packed tid-list columns + AND-popcount)."""
from repro.mining.eclat.miner import EclatMiner, columnize_cost

__all__ = ["EclatMiner", "columnize_cost"]
