"""EclatMiner — the vertical tid-list formulation of the mining plane.

Where :class:`repro.pipeline.MarketBasketPipeline` keeps transactions
horizontal (bitmap rows) and re-scans the whole bitmap every level, this
plane transposes once — each item owns a packed-uint32 tid-list *column*
(bit b of word w ⇔ transaction ``32w + b``, the ``pack_words``
convention) — and every later level is pure row-aligned work:

  k=1   support(i)        = popcount(col_i)
  k>=2  support(prefix+(a,b)) = popcount(slab[prefix+(a,)] & slab[prefix+(b,)])

because ``generate_candidates`` builds each k-candidate by joining two
(k-1)-siblings that differ only in the last item — so the candidate's
tidset is exactly the AND of two rows the previous level already
materialized.  The transaction axis is paid for once at columnization;
each round then touches ``candidates × n_tx/32`` words instead of
``n_tx × n_items`` lanes, which is why Eclat wins on dense data (B11).

Everything around the formulation is deliberately identical to the
Apriori plane: same ``generate_candidates``/``generate_rules`` control
plane, same min-support semantics, same ``Runtime`` phase routing (serial
candgen/columnize/rules + tiled map rounds under whatever
``policy=static|dynamic|costmodel`` says), same ``PipelineReport`` shape
— the parity tests and the ``--smoke`` path hold supports and rules
bit-identical between the two.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import AprioriResult, generate_candidates
from repro.core.mapreduce import FailureEvent, MapReduceJob, SimulatedCluster
from repro.core.power import PowerModel
from repro.core.rules import generate_rules
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.data.sparse import SparseSlab, pack_tid_columns
from repro.kernels.support_count.ops import intersect_count
from repro.kernels.support_count.ref import intersect_count_ref
from repro.pipeline.pipeline import (Baskets, PipelineConfig, PipelineResult,
                                     candgen_cost, ingest_baskets)
from repro.pipeline.dataplane import resolve_backend
from repro.pipeline.report import PipelineReport, RoundReport
from repro.runtime import (MeasuredPhase, Runtime, SlabPool, SwitchingPolicy,
                           autotuned_costmodel, donated_add, donated_and)

_jitted_intersect_ref = jax.jit(intersect_count_ref)

WORD_BITS = 32

# ops per packed word-pair in flop-equivalents (matches
# shape_flops_bytes("intersect_count", ...): 2 bit-ops per item, 32
# items per word) — the roofline seed for the map phases' tile_flops
_FLOPS_PER_WORD = 64.0


def columnize_cost(nnz: int, n_rows: int, n_words: int) -> float:
    """Work units for the serial transpose/pack phase: one touch per nnz
    cell plus the packed slab write, in the same byte-flavored units the
    map tiles use (so serial and map phases share one time axis)."""
    return max(1.0, 4.0 * nnz + 4.0 * n_rows * n_words)


class EclatMiner:
    """Vertical mining over a heterogeneity profile (Apriori's twin)."""

    def __init__(self, profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[PipelineConfig] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None,
                 policy: Union[str, SwitchingPolicy, None] = None):
        self.profile = profile or HeterogeneityProfile.paper()
        self.config = config or PipelineConfig()
        cfg = self.config
        policy = policy if policy is not None else cfg.policy
        if policy == "costmodel" and cfg.autotune:
            # this plane's hot loop is the intersect kernel, so the cost
            # model plans on *its* measured walls, not support_count's
            policy = autotuned_costmodel("intersect_count")
        self.runtime = Runtime(
            self.profile,
            policy=policy,
            split=cfg.split,
            power=power if power is not None else cfg.power,
            scheduler=scheduler)
        self.scheduler = self.runtime.scheduler
        self.power = self.runtime.power
        self.cluster = SimulatedCluster(self.profile, self.scheduler,
                                        power=None)  # ledger prices energy
        self.backend = resolve_backend(cfg.data_plane)
        self.interpret = cfg.interpret
        self.tuning = None if cfg.autotune else False
        # round-persistent donated count accumulators (pipelined rounds)
        self.slabs = SlabPool()

    # ------------------------------------------------------------------
    # vertical data plane
    # ------------------------------------------------------------------
    def _columnize(self, baskets: Baskets) -> Tuple[np.ndarray, int, int, int]:
        """Returns ``(tid columns [rows_pad128, W_pad128] uint32, raw item
        count, raw tx count, nnz)``.  A :class:`SparseSlab` columnizes
        straight from CSR — the dense bitmap is never materialized on the
        sparse path; dense bitmaps / id lists share ``ingest_baskets``'s
        validation so all input forms agree byte-for-byte."""
        if isinstance(baskets, SparseSlab):
            return (baskets.tid_columns(), baskets.n_items, baskets.n_tx,
                    baskets.nnz)
        T, n_items_raw, n_tx_raw = ingest_baskets(baskets)
        return (pack_tid_columns(T), n_items_raw, n_tx_raw,
                int(np.asarray(T, dtype=np.int64).sum()))

    def _count(self, A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
        """Row-aligned intersection counts (backend-dispatched)."""
        if self.backend == "pallas":
            return intersect_count(A, B, interpret=self.interpret,
                                   tuning=self.tuning)
        return _jitted_intersect_ref(A, B)

    def _pair_tiles(self, A: jnp.ndarray, B: jnp.ndarray
                    ) -> List[Tuple[int, jnp.ndarray, jnp.ndarray]]:
        """Split two aligned [M, W] slabs into uniform row-tile pairs
        ``(row offset, A tile, B tile)``.  Identical tile shapes are the
        same jit-cache requirement the horizontal plane's ``uniform_tiles``
        enforces; all-zero padding rows popcount to 0 (inert)."""
        m = A.shape[0]
        n_tiles = max(1, min(self.config.n_tiles, m))
        rows = -(-m // n_tiles)
        rows += (-rows) % 128                     # kernel lane alignment
        n_tiles = -(-m // rows)
        pad = rows * n_tiles - m
        if pad:
            z = jnp.zeros((pad, A.shape[1]), dtype=A.dtype)
            A = jnp.concatenate([A, z])
            B = jnp.concatenate([B, z])
        return [(i * rows, A[i * rows:(i + 1) * rows],
                 B[i * rows:(i + 1) * rows]) for i in range(n_tiles)]

    def _map_round(self, name: str, A: jnp.ndarray, B: jnp.ndarray,
                   m_true: int, failures: Optional[List[FailureEvent]]):
        """One tiled intersection phase through the shared runtime.

        Pipelined (default): every tile scatters its device counts into a
        tile-offset window of an [m_pad] vector, partials fold into a
        donated slab accumulator, and the round reads back one sliced
        vector — one sync.  ``per_tile`` keeps the legacy readback per
        tile (the B13 baseline)."""
        tiles = self._pair_tiles(A, B)
        n_words = A.shape[1]
        meter = self.runtime.meter
        pipelined = self.config.round_execution == "pipelined"

        if pipelined:
            rows = int(tiles[0][1].shape[0])
            m_pad = rows * len(tiles)

            def map_fn(tile):
                off, Aj, Bj = tile
                return (jnp.zeros(m_pad, jnp.int32)
                        .at[off:off + rows]
                        .set(self._count(Aj, Bj).astype(jnp.int32)))

            def finalize(acc):
                out = meter.d2h(acc[:m_true], dtype=np.int64)
                self.slabs.give(acc)
                return out

            job = MapReduceJob(
                name=name,
                map_fn=map_fn,
                combine_fn=donated_add,
                zero_fn=lambda: self.slabs.take((m_pad,), jnp.int32),
                cost_fn=lambda t: float(t[1].nbytes + t[2].nbytes),
            )
        else:
            finalize = None

            def tile_counts(tile) -> np.ndarray:
                off, Aj, Bj = tile
                counts = meter.d2h(self._count(Aj, Bj), dtype=np.int64)
                out = np.zeros(m_true, dtype=np.int64)
                seg = counts[:max(0, min(len(counts), m_true - off))]
                out[off:off + len(seg)] = seg
                return out

            job = MapReduceJob(
                name=name,
                map_fn=tile_counts,
                combine_fn=lambda a, b: a + b,  # disjoint segments
                zero_fn=lambda m=m_true: np.zeros(m, dtype=np.int64),
                cost_fn=lambda t: float(t[1].nbytes + t[2].nbytes),
            )
        tile_costs = np.array([job.tile_cost(t) for t in tiles],
                              dtype=np.float64)
        tile_rows = np.array([t[1].shape[0] for t in tiles], dtype=np.float64)
        # one family across rounds, like the horizontal plane's "mba-map":
        # dynamic switching tracks owner drift over same-arity rounds
        task = TaskSpec(name, float(tile_costs.sum()), parallel=True,
                        n_tiles=len(tiles), family="eclat-map")

        def execute(asg, _costs):
            result, rep = self.cluster.run(job, tiles, failures=failures,
                                           speculate=self.config.speculate,
                                           assignment=asg)
            if finalize is not None:
                result = finalize(result)   # the round's single sync
            return MeasuredPhase(result=result, busy_s=rep.busy_s,
                                 makespan=rep.makespan,
                                 switches=rep.switches, reissued=rep.reissued,
                                 failed_devices=list(rep.failed_devices),
                                 tiles_done=rep.tiles_done)

        return self.runtime.run_phase(
            task, execute, tile_costs=tile_costs,
            tile_flops=_FLOPS_PER_WORD * tile_rows * n_words)

    # ------------------------------------------------------------------
    def run(self, baskets: Baskets,
            failures: Optional[List[FailureEvent]] = None) -> PipelineResult:
        cfg = self.config
        rt = self.runtime
        t_start = time.perf_counter()
        rt.ledger.take_since(0)                  # drop orphans (plane-owned)
        mark = rt.ledger.mark()

        # ---- columnize: the one serial pass over the transaction axis --
        if isinstance(baskets, SparseSlab):
            nnz0, ni0, ntx0 = baskets.nnz, baskets.n_items, baskets.n_tx
        elif isinstance(baskets, np.ndarray):
            nnz0 = int(np.asarray(baskets, dtype=np.int64).sum())
            ntx0, ni0 = baskets.shape
        else:
            nnz0 = sum(len(set(tx)) for tx in baskets)
            ntx0, ni0 = len(baskets), 0     # universe unknown until packed
        (cols, n_items_raw, n_tx_raw, nnz), col_rec = rt.run_serial(
            "eclat-columnize",
            cost=columnize_cost(nnz0, max(ni0, 1),
                                1 + max(ntx0 - 1, 0) // WORD_BITS),
            fn=lambda: self._columnize(baskets),
            min_speed=cfg.serial_min_speed)
        min_sup = cfg.abs_support(n_tx_raw)
        n_words = cols.shape[1]
        cols = rt.meter.h2d(cols)                # device-resident once

        report = PipelineReport(
            backend=self.backend, policy=rt.policy.name,
            algorithm="eclat", split=rt.split,
            profile_speeds=[float(s) for s in self.profile.speeds],
            n_tx=n_tx_raw, n_items=n_items_raw,
            n_tiles=cfg.n_tiles, min_support=min_sup)
        supports: Dict[Tuple[int, ...], int] = {}

        # ---- round k=1: popcount of each item's own column -------------
        counts, rec = self._map_round("eclat-round1-item-counts",
                                      cols, cols, n_items_raw, failures)
        frequent = [(int(i),) for i in np.nonzero(counts >= min_sup)[0]]
        # the (k-1)-level slab: one tid-list row per frequent itemset
        row_of = {(int(i),): int(i) for (i,) in frequent}
        slab = cols
        for (i,) in frequent:
            supports[(i,)] = int(counts[i])
        report.rounds.append(RoundReport.from_phases(
            k=1, n_candidates=n_items_raw, n_frequent=len(frequent),
            map_phase=rec))

        # ---- rounds k>=2: serial join + tiled AND-popcount -------------
        k = 2
        while frequent and (cfg.max_k == 0 or k <= cfg.max_k):
            cands, serial = rt.run_serial(
                f"eclat-candgen-k{k}",
                cost=candgen_cost(len(frequent), k, cfg.serial_unit_cost),
                fn=lambda fr=frequent: generate_candidates(fr),
                min_speed=cfg.serial_min_speed)
            if not cands:
                report.rounds.append(RoundReport.from_phases(
                    k=k, n_candidates=0, n_frequent=0, map_phase=None,
                    serial=serial, n_devices=self.profile.n))
                break

            # stage the join's two (k-1)-parents per candidate: c joins
            # c[:-1] with c[:-2]+(c[-1],) — both frequent by construction
            left = np.array([row_of[c[:-1]] for c in cands], dtype=np.int32)
            right = np.array([row_of[c[:-2] + (c[-1],)] for c in cands],
                             dtype=np.int32)
            A = jnp.take(slab, rt.meter.h2d(left), axis=0)
            B = jnp.take(slab, rt.meter.h2d(right), axis=0)

            sup, rec = self._map_round(f"eclat-round{k}-intersect",
                                       A, B, len(cands), failures)
            frequent = []
            surv_rows: List[int] = []
            for row, (c, s) in enumerate(zip(cands, sup)):
                if s >= min_sup:
                    supports[c] = int(s)
                    frequent.append(c)
                    surv_rows.append(row)
            # next level's slab: materialize survivors' tidsets only
            # (uncharged staging, like the horizontal plane's
            # itemsets_to_bitmap + prepare)
            if frequent:
                surv = rt.meter.h2d(np.array(surv_rows, dtype=np.int32))
                # donated AND: the two gathered parent slabs die here, so
                # the survivor tidsets are written in place of one of them
                slab = donated_and(jnp.take(A, surv, axis=0),
                                   jnp.take(B, surv, axis=0))
                row_of = {c: r for r, c in enumerate(frequent)}
            m_padded = -(-len(cands) // 128) * 128
            report.rounds.append(RoundReport.from_phases(
                k=k, n_candidates=len(cands), n_frequent=len(frequent),
                map_phase=rec, serial=serial, m_padded=m_padded))
            k += 1

        # ---- association rules (identical serial phase) ----------------
        rules, rules_rec = rt.run_serial(
            "mba-rules",
            cost=max(1.0, len(supports) * cfg.serial_unit_cost),
            fn=lambda: generate_rules(
                AprioriResult(supports=supports, n_tx=n_tx_raw, levels=k - 1),
                cfg.min_confidence, min_lift=cfg.min_lift),
            min_speed=cfg.serial_min_speed)
        report.rules_phase = rules_rec

        report.n_itemsets = len(supports)
        report.n_rules = len(rules)
        report.wall_time_s = time.perf_counter() - t_start
        report.ledger = rt.ledger.take_since(mark)
        return PipelineResult(supports=supports, rules=rules, report=report,
                              n_tx=n_tx_raw)
