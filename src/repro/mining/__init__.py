"""Mining backends: algorithm formulations behind one protocol.

The horizontal (Apriori) plane lives in :mod:`repro.pipeline`; this
package adds the vertical (Eclat) formulation, the cost-model
auto-selector that picks between them per dataset, and the out-of-core
SON plane that partitions corpora larger than device memory into
disk-resident chunks with crash-safe checkpointed resume.
"""
from repro.mining.backend import (ALGORITHMS, MiningBackend, make_miner,
                                  resolve_algorithm)
from repro.mining.eclat.miner import EclatMiner
from repro.mining.select import (AlgorithmChoice, AlgorithmCostModel,
                                 local_min_support, partition_stats,
                                 select_algorithm,
                                 select_partition_algorithm)
from repro.mining.son import SONConfig, SONKilled, SONMiner

__all__ = [
    "ALGORITHMS", "AlgorithmChoice", "AlgorithmCostModel", "EclatMiner",
    "MiningBackend", "SONConfig", "SONKilled", "SONMiner",
    "local_min_support", "make_miner", "partition_stats",
    "resolve_algorithm", "select_algorithm", "select_partition_algorithm",
]
