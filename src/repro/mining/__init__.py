"""Mining backends: algorithm formulations behind one protocol.

The horizontal (Apriori) plane lives in :mod:`repro.pipeline`; this
package adds the vertical (Eclat) formulation plus the cost-model
auto-selector that picks between them per dataset.
"""
from repro.mining.backend import (ALGORITHMS, MiningBackend, make_miner,
                                  resolve_algorithm)
from repro.mining.eclat.miner import EclatMiner
from repro.mining.select import (AlgorithmChoice, AlgorithmCostModel,
                                 select_algorithm)

__all__ = [
    "ALGORITHMS", "AlgorithmChoice", "AlgorithmCostModel", "EclatMiner",
    "MiningBackend", "make_miner", "resolve_algorithm", "select_algorithm",
]
