"""Association-rule generation (paper §V step 3).

Map phase: prune candidate itemsets by minimum confidence and emit rules;
reduce phase: collect.  Host-side enumeration is the (small) control plane;
all supports were computed on-device in step 2.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core.itemsets import AprioriResult


@dataclass(frozen=True)
class Rule:
    antecedent: Tuple[int, ...]
    consequent: Tuple[int, ...]
    support: float          # supp(A ∪ B) / n_tx
    confidence: float       # supp(A ∪ B) / supp(A)
    lift: float             # confidence / (supp(B) / n_tx)

    def __str__(self):
        a = ",".join(map(str, self.antecedent))
        b = ",".join(map(str, self.consequent))
        return (f"{{{a}}} => {{{b}}}  supp={self.support:.4f} "
                f"conf={self.confidence:.3f} lift={self.lift:.2f}")


def generate_rules(result: AprioriResult, min_confidence: float,
                   min_lift: float = 0.0) -> List[Rule]:
    rules: List[Rule] = []
    supports = result.supports
    n = float(result.n_tx)
    for itemset, supp in supports.items():
        if len(itemset) < 2:
            continue
        for r in range(1, len(itemset)):
            for ante in itertools.combinations(itemset, r):
                sa = supports.get(tuple(sorted(ante)))
                if not sa:
                    continue
                conf = supp / sa
                if conf < min_confidence:
                    continue
                cons = tuple(sorted(set(itemset) - set(ante)))
                sb = supports.get(cons)
                if sb is None:
                    continue
                lift = conf / (sb / n)
                if lift >= min_lift:
                    rules.append(Rule(tuple(sorted(ante)), cons,
                                      supp / n, conf, lift))
    # total order: (confidence, support) ties are common (many perfect-
    # confidence rules), and supports-dict insertion order would otherwise
    # leak into the result — the serving index build relies on this being
    # reproducible across processes
    rules.sort(key=lambda r: (-r.confidence, -r.support, -r.lift,
                              r.antecedent, r.consequent))
    return rules
