"""Power / core-switching model (paper §VI).

The paper's claims: (a) switching off unused cores reduces power; (b) the
cost of core switching must not exceed the heterogeneity benefit; (c)
switching is static (known order) or dynamic (MB Scheduler decides online).

We model per-core active/idle/gated wattage plus a per-switch energy charge,
and expose the comparisons the paper argues for.  Two built-in calibrations:
``cpu`` (a heterogeneous 4-core CPU, watts ∝ speed) and ``tpu_v5e`` (public
~200 W active per chip estimate).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.scheduler import Assignment


@dataclass
class PowerModel:
    p_active: np.ndarray       # [n] W while executing
    p_idle: np.ndarray         # [n] W while on but idle
    p_gated: np.ndarray        # [n] W while switched off
    switch_joules: float = 0.5  # energy charged per core switch / migration

    @classmethod
    def cpu(cls, profile: HeterogeneityProfile, w_per_speed: float = 0.05,
            idle_frac: float = 0.35, gated_w: float = 0.2) -> "PowerModel":
        act = profile.speeds * w_per_speed
        return cls(act, act * idle_frac, np.full(profile.n, gated_w))

    @classmethod
    def tpu_v5e(cls, n: int) -> "PowerModel":
        return cls(np.full(n, 200.0), np.full(n, 90.0), np.full(n, 15.0),
                   switch_joules=50.0)

    # ------------------------------------------------------------------
    def energy(self, busy_s: np.ndarray, makespan: float,
               gated: Optional[list] = None, switches: int = 0,
               gate_idle: bool = True) -> float:
        """Total joules for one job execution.

        busy_s[d]: seconds device d actually computed; devices in `gated`
        are off for the whole job; non-gated devices idle (makespan - busy).
        """
        busy_s = np.asarray(busy_s, dtype=np.float64)
        gated = set(gated or [])
        total = 0.0
        for d in range(len(busy_s)):
            if d in gated and gate_idle:
                total += self.p_gated[d] * makespan
            else:
                total += self.p_active[d] * busy_s[d]
                total += self.p_idle[d] * max(makespan - busy_s[d], 0.0)
        return total + switches * self.switch_joules

    # ------------------------------------------------------------------
    def energy_of(self, asg: Assignment, tile_costs: np.ndarray,
                  profile: HeterogeneityProfile, switches: int = 0,
                  gate_idle: bool = True) -> float:
        load = np.array([tile_costs[ts].sum() if ts else 0.0
                         for ts in asg.tiles_of])
        busy = load / profile.speeds
        return self.energy(busy, asg.makespan, asg.gated if gate_idle else [],
                           switches=switches, gate_idle=gate_idle)
