"""JAX version compatibility shims.

The codebase targets the current jax API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.lax.axis_size``); this container
ships jax 0.4.37, where the ambient-mesh machinery is still private.  Every
fallback here routes through one function so call sites stay clean and the
shims can be deleted wholesale once the floor moves past 0.5.
"""
from __future__ import annotations

import contextlib

import jax


def axis_size(axis_name: str) -> int:
    """Size of a bound mesh axis inside shard_map (jax >= 0.5 has
    lax.axis_size; 0.4.x resolves psum-of-1 statically)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where supported
    (jax >= 0.5); 0.4.x has no AxisType and defaults are equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def get_abstract_mesh():
    """The ambient abstract mesh, or an empty/None mesh outside a context."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib
    mesh = _mesh_lib.get_abstract_mesh()
    # 0.4.x holds a bare () sentinel outside any context — map it to None
    if not getattr(mesh, "axis_names", None):
        return None
    return mesh


def mesh_context(mesh):
    """Context manager making `mesh` ambient: sharding constraints may use
    bare PartitionSpecs and ``get_abstract_mesh`` sees it."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)

    from jax._src import mesh as _mesh_lib

    @contextlib.contextmanager
    def _ctx():
        # 0.4.x: the physical mesh enables bare-P sharding constraints, the
        # abstract mesh feeds get_abstract_mesh() consumers.
        with mesh, _mesh_lib.set_abstract_mesh(mesh.abstract_mesh):
            yield

    return _ctx()
