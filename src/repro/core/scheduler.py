"""MB Scheduler — the paper's contribution (§V functions 1–5), TPU-native.

Responsibilities (paper wording → implementation):

1. "Collect the tasks submitted to the task tracker"   → :class:`TaskSpec`
   queue with explicit cost estimates (bytes / FLOPs per map shard).
2. "Analyse single- vs multi-threaded"                 → ``TaskSpec.parallel``.
3. Single-threaded → most appropriate core, others off → :meth:`assign_serial`
   (returns the chosen device + the gating set for the power model).
4. Multi-threaded → split across cores, run simultaneously, combine
   → :meth:`assign_parallel`: tile-level **proportional split** (largest
   remainder) or **LPT** (earliest-finish-time greedy) over heterogeneous
   speeds.
5. Reducer collects and combines                        → the MapReduce
   engine consumes the :class:`Assignment`; combiners are associative so
   re-issued (speculative) shards merge idempotently.

Dynamic core switching = :meth:`rebalance` (re-plan from EWMA-updated
speeds, reporting which tiles moved — each move is a "core switch" whose
cost the power model charges).  Straggler mitigation = :meth:`speculate`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hetero import HeterogeneityProfile


@dataclass(frozen=True)
class TaskSpec:
    """A schedulable task (one MapReduce phase or a serial driver phase)."""

    name: str
    cost: float                    # work units (e.g. bytes of transaction data)
    parallel: bool = True          # paper: multi- vs single-threaded
    n_tiles: int = 0               # parallel tasks are pre-split into tiles
    min_speed: float = 0.0         # serial tasks: required core capability
    # phases with the same family and tile arity recur over the same tile
    # set (mining rounds over one tiled bitmap, serving batches of one
    # bucket) — dynamic switching tracks plan drift within a family
    family: str = ""               # defaults to `name`

    def tile_cost(self) -> float:
        return self.cost / max(self.n_tiles, 1)

    @property
    def family_key(self) -> str:
        return self.family or self.name


@dataclass
class Assignment:
    """tiles_of[d] = tile ids owned by device d; device -1 = dropped."""

    tiles_of: List[List[int]]
    est_finish: np.ndarray                 # [n_devices] seconds
    gated: List[int] = field(default_factory=list)   # powered-off devices
    serial_device: Optional[int] = None
    # assign_serial could not satisfy the task's min_speed and fell back to
    # the fastest core — surfaced in the phase record, never hidden
    constraint_violated: bool = False

    @property
    def makespan(self) -> float:
        return float(self.est_finish.max()) if len(self.est_finish) else 0.0

    def owner_of(self) -> Dict[int, int]:
        return {t: d for d, ts in enumerate(self.tiles_of) for t in ts}


class MBScheduler:
    """Heterogeneity-aware scheduler over a device profile."""

    def __init__(self, profile: HeterogeneityProfile, policy: str = "lpt"):
        if policy not in ("lpt", "proportional", "equal"):
            raise ValueError(policy)
        self.profile = profile
        self.policy = policy
        self.switches = 0                 # core-switch counter (power model)

    # ------------------------------------------------------------------
    # paper function 3: single-threaded task -> best core, gate the rest
    # ------------------------------------------------------------------
    def assign_serial(self, task: TaskSpec,
                      device: Optional[int] = None) -> Assignment:
        """`device` pins the task (the sharded runtime routes driver-side
        phases to rank 0, where the host process lives); otherwise the most
        capable core meeting `min_speed` wins."""
        speeds = self.profile.speeds
        violated = False
        if device is not None:
            dev = int(device)
            violated = speeds[dev] < task.min_speed
        else:
            ok = np.where(speeds >= task.min_speed)[0]
            if len(ok):
                dev = int(ok[np.argmax(speeds[ok])])
            else:               # no core qualifies: fastest core, flagged
                dev = int(np.argmax(speeds))
                violated = True
        finish = np.zeros(self.profile.n)
        finish[dev] = task.cost / speeds[dev]
        gated = [d for d in range(self.profile.n) if d != dev]
        return Assignment([[0] if d == dev else [] for d in range(self.profile.n)],
                          finish, gated=gated, serial_device=dev,
                          constraint_violated=bool(violated))

    # ------------------------------------------------------------------
    # paper function 4: multi-threaded task -> proportional / LPT split
    # ------------------------------------------------------------------
    def assign_parallel(self, task: TaskSpec,
                        tile_costs: Optional[np.ndarray] = None) -> Assignment:
        n_tiles = task.n_tiles or 1
        if tile_costs is None:
            tile_costs = np.full(n_tiles, task.tile_cost())
        tile_costs = np.asarray(tile_costs, dtype=np.float64)
        assert len(tile_costs) == n_tiles
        if self.policy == "equal":
            return self._equal_split(tile_costs)
        if self.policy == "proportional":
            return self._proportional(tile_costs)
        return self._lpt(tile_costs)

    # -- naive Hadoop-style equal split (the paper's baseline) ----------
    def _equal_split(self, tile_costs: np.ndarray) -> Assignment:
        n, D = len(tile_costs), self.profile.n
        tiles_of: List[List[int]] = [[] for _ in range(D)]
        for t in range(n):
            tiles_of[t % D].append(t)
        return self._finish(tiles_of, tile_costs)

    # -- proportional split (largest-remainder, paper §V function 4) ----
    def _proportional(self, tile_costs: np.ndarray) -> Assignment:
        n, D = len(tile_costs), self.profile.n
        shares = self.profile.shares() * n
        base = np.floor(shares).astype(int)
        rem = n - base.sum()
        order = np.argsort(-(shares - base))
        base[order[:rem]] += 1
        tiles_of: List[List[int]] = [[] for _ in range(D)]
        t = 0
        for d in range(D):
            tiles_of[d] = list(range(t, t + base[d]))
            t += base[d]
        return self._finish(tiles_of, tile_costs)

    # -- LPT / earliest-finish-time greedy (heterogeneous machines) -----
    def _lpt(self, tile_costs: np.ndarray) -> Assignment:
        D = self.profile.n
        speeds = self.profile.speeds
        tiles_of: List[List[int]] = [[] for _ in range(D)]
        load = np.zeros(D)
        for t in np.argsort(-tile_costs):
            d = int(np.argmin((load + tile_costs[t]) / speeds))
            tiles_of[d].append(int(t))
            load[d] += tile_costs[t]
        return self._finish(tiles_of, tile_costs)

    def _finish(self, tiles_of: List[List[int]], tile_costs: np.ndarray) -> Assignment:
        load = np.array([tile_costs[ts].sum() if ts else 0.0 for ts in tiles_of])
        finish = load / self.profile.speeds
        gated = [d for d in range(self.profile.n) if not tiles_of[d]]
        return Assignment(tiles_of, finish, gated=gated)

    # ------------------------------------------------------------------
    # dynamic core switching (paper §VI): re-plan after EWMA updates
    # ------------------------------------------------------------------
    def rebalance(self, task: TaskSpec, old: Assignment,
                  tile_costs: Optional[np.ndarray] = None) -> Tuple[Assignment, int]:
        """Returns (new assignment, #tiles that changed owner)."""
        new = self.assign_parallel(task, tile_costs)
        before, after = old.owner_of(), new.owner_of()
        moved = sum(1 for t, d in after.items() if before.get(t, d) != d)
        self.switches += moved
        return new, moved

    # ------------------------------------------------------------------
    # straggler mitigation: speculative re-issue (Hadoop heritage)
    # ------------------------------------------------------------------
    def speculate(self, assignment: Assignment, progress: np.ndarray,
                  threshold: float = 0.7) -> List[Tuple[int, int]]:
        """progress[d] in [0,1] per device.  Devices whose progress lags the
        median by `threshold` get their remaining tiles re-issued to the
        fastest under-loaded devices.  Returns [(tile, new_device)]."""
        med = float(np.median(progress))
        if med <= 0:
            return []
        lagging = [d for d in range(self.profile.n)
                   if progress[d] < threshold * med and assignment.tiles_of[d]]
        idle = sorted((d for d in range(self.profile.n)
                       if progress[d] >= 0.999 or not assignment.tiles_of[d]),
                      key=lambda d: -self.profile.speeds[d])
        moves: List[Tuple[int, int]] = []
        for straggler, helper in zip(lagging, idle):
            n_rem = max(1, int(len(assignment.tiles_of[straggler])
                               * (1 - progress[straggler])))
            for t in assignment.tiles_of[straggler][-n_rem:]:
                moves.append((t, helper))
        self.switches += len(moves)
        return moves

    # ------------------------------------------------------------------
    # commit speculative moves: without this, the straggler still owns the
    # re-issued tiles and a repeated speculate() re-issues the very same
    # ones — the assignment must be mutated for the loop to close
    # ------------------------------------------------------------------
    def apply_moves(self, assignment: Assignment,
                    moves: Sequence[Tuple[int, int]],
                    tile_costs: np.ndarray) -> Assignment:
        """Re-home each ``(tile, new_device)`` and re-derive finish times.

        Returns a fresh :class:`Assignment` (est_finish / gated recomputed
        from the moved tile sets); the input assignment is not mutated.
        """
        if not moves:
            return assignment
        tiles_of = [list(ts) for ts in assignment.tiles_of]
        owner = {t: d for d, ts in enumerate(tiles_of) for t in ts}
        for t, dst in moves:
            src = owner.get(t)
            if src is None:
                raise ValueError(f"move of unassigned tile {t}")
            if src == dst:
                continue
            tiles_of[src].remove(t)
            tiles_of[dst].append(t)
            owner[t] = dst
        new = self._finish(tiles_of, np.asarray(tile_costs, dtype=np.float64))
        new.serial_device = assignment.serial_device
        new.constraint_violated = assignment.constraint_violated
        return new

    # ------------------------------------------------------------------
    # lower bound for tests: makespan >= max(total/Σspeed, max_tile/fastest)
    # ------------------------------------------------------------------
    def makespan_lower_bound(self, tile_costs: np.ndarray) -> float:
        total = float(np.sum(tile_costs))
        return max(total / self.profile.total_speed,
                   float(np.max(tile_costs)) / float(np.max(self.profile.speeds)))


def simulate_makespan(assignment: Assignment, tile_costs: np.ndarray,
                      profile: HeterogeneityProfile) -> float:
    """Deterministic execution-time simulation of an assignment."""
    load = np.array([tile_costs[ts].sum() if ts else 0.0
                     for ts in assignment.tiles_of])
    return float((load / profile.speeds).max())
