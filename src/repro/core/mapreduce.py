"""MapReduce engine — Hadoop semantics, TPU-native execution.

Two runtimes share one :class:`MapReduceJob` definition:

* :class:`SimulatedCluster` — deterministic event simulation over a
  :class:`HeterogeneityProfile` (the paper's 4-core system, a straggler-laden
  pod, ...).  Computes the *real* result (every tile mapped exactly once,
  combined associatively) and a timing/energy report under the MB Scheduler,
  including failures (tiles of a dead device re-planned — "dynamic core
  switching") and speculative re-issue.
* :func:`run_sharded` — `shard_map` execution over a JAX mesh axis: map
  runs on-device per shard, the reduce is a `psum` combiner tree.  This is
  the path the pod actually executes; the simulator is the scheduler's
  planning/evaluation model (and the benchmark harness for the paper's
  claims, since this container has one real device).
"""
from __future__ import annotations

import functools
import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.hetero import HeterogeneityProfile
from repro.core.power import PowerModel
from repro.core.scheduler import Assignment, MBScheduler, TaskSpec


@dataclass(frozen=True)
class MapReduceJob:
    """map: tile -> value; combine: value × value -> value (associative)."""

    name: str
    map_fn: Callable[[Any], Any]
    combine_fn: Callable[[Any, Any], Any]
    zero_fn: Callable[[], Any]
    cost_fn: Optional[Callable[[Any], float]] = None   # work units per tile

    def tile_cost(self, tile) -> float:
        if self.cost_fn is not None:
            return float(self.cost_fn(tile))
        if hasattr(tile, "nbytes"):
            return float(tile.nbytes)
        return 1.0


@dataclass
class ExecReport:
    makespan: float
    busy_s: np.ndarray
    waves: int = 1
    switches: int = 0
    reissued: int = 0
    failed_devices: List[int] = field(default_factory=list)
    energy_j: Optional[float] = None
    assignment: Optional[Assignment] = None
    tiles_done: Optional[List[int]] = None   # tiles *executed* per device
    # (differs from assignment.tiles_of after failures: orphaned tiles are
    # counted at the survivor that re-ran them)


@dataclass
class FailureEvent:
    device: int
    at_time: float


class SimulatedCluster:
    """Event-driven simulation of a heterogeneous cluster executing a job."""

    def __init__(self, profile: HeterogeneityProfile,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None):
        self.profile = profile
        self.scheduler = scheduler or MBScheduler(profile)
        self.power = power

    # ------------------------------------------------------------------
    def run(self, job: MapReduceJob, tiles: Sequence[Any],
            failures: Optional[List[FailureEvent]] = None,
            speculate: bool = True,
            assignment: Optional[Assignment] = None) -> Tuple[Any, ExecReport]:
        """`assignment` pins a pre-planned placement (the shared Runtime
        plans through its SwitchingPolicy and passes the result here);
        otherwise this cluster's scheduler plans statically."""
        tile_costs = np.array([job.tile_cost(t) for t in tiles], dtype=np.float64)
        if assignment is not None:
            asg = assignment
        else:
            task = TaskSpec(job.name, float(tile_costs.sum()), parallel=True,
                            n_tiles=len(tiles))
            asg = self.scheduler.assign_parallel(task, tile_costs)
        report = self._simulate(asg, tile_costs, failures or [], speculate)
        report.assignment = asg
        if self.power is not None:
            # same joule definition as Runtime.run_phase: cores that ran
            # nothing are gated, and every migration (switch OR re-issue)
            # is priced
            gated = [d for d in range(self.profile.n)
                     if report.busy_s[d] == 0.0]
            report.energy_j = self.power.energy(
                report.busy_s, report.makespan, gated=gated,
                switches=report.switches + report.reissued)
        # --- actual computation: every tile exactly once, combiner tree ---
        result = job.zero_fn()
        for t in tiles:
            result = job.combine_fn(result, job.map_fn(t))
        return result, report

    # ------------------------------------------------------------------
    def _simulate(self, asg: Assignment, tile_costs: np.ndarray,
                  failures: List[FailureEvent], speculate: bool) -> ExecReport:
        D = self.profile.n
        speeds = self.profile.speeds
        fail_at = {f.device: f.at_time for f in failures}
        queues: List[List[int]] = [list(ts) for ts in asg.tiles_of]
        busy = np.zeros(D)
        clock = np.zeros(D)                      # per-device current time
        done: set = set()
        alive = [d for d in range(D)]
        switches, reissued = 0, 0
        pending = {t for q in queues for t in q}
        done_by = [0] * D

        def run_queue(d: int):
            nonlocal switches
            q = queues[d]
            while q:
                t = q[0]
                dt = tile_costs[t] / speeds[d]
                if d in fail_at and clock[d] + dt > fail_at[d]:
                    return False                  # dies mid-tile
                q.pop(0)
                clock[d] += dt
                busy[d] += dt
                done.add(t)
                done_by[d] += 1
                pending.discard(t)
            return True

        # first pass
        dead: List[int] = []
        for d in list(alive):
            ok = run_queue(d)
            if not ok:
                dead.append(d)
                alive.remove(d)
                clock[d] = fail_at[d]
        # dynamic re-planning of orphaned tiles (paper: dynamic switching)
        orphans = sorted(pending)
        while orphans:
            if not alive:
                raise RuntimeError("all devices failed")
            # LPT over survivors, starting at their current clocks
            for t in sorted(orphans, key=lambda t: -tile_costs[t]):
                d = min(alive, key=lambda d: clock[d] + tile_costs[t] / speeds[d])
                dt = tile_costs[t] / speeds[d]
                clock[d] += dt
                busy[d] += dt
                done.add(t)
                done_by[d] += 1
                switches += 1
            pending.difference_update(orphans)
            orphans = []
        makespan = float(clock.max())
        # speculative re-issue: if one device dominates the tail, clone its
        # last tile onto the fastest idle device and take the min finish.
        if speculate and alive:
            slowest = int(np.argmax(clock))
            others = [d for d in alive if d != slowest]
            if others and asg.tiles_of[slowest]:
                helper = max(others, key=lambda d: speeds[d])
                t = asg.tiles_of[slowest][-1]
                alt = clock[helper] + tile_costs[t] / speeds[helper]
                orig = clock[slowest]
                if alt < orig - 1e-12:
                    reissued += 1
                    makespan = float(max(np.delete(clock, slowest).max() if D > 1 else 0.0,
                                         min(orig, alt),
                                         clock[slowest] - tile_costs[t] / speeds[slowest]))
        # switches is per-run (this job's re-planned tiles only); the
        # scheduler keeps its own lifetime counter for rebalance/speculate
        return ExecReport(makespan=makespan, busy_s=busy,
                          switches=switches,
                          reissued=reissued, failed_devices=dead,
                          tiles_done=done_by)


# ---------------------------------------------------------------------------
# Real distributed execution: shard_map + psum combiner tree
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _sharded_fn(job: MapReduceJob, mesh, axis: str, n_extra: int):
    """Build (and cache) the jitted shard_map program for one job/mesh pair.

    The cache key is the *job object* (frozen dataclass → hashable): callers
    that reuse one MapReduceJob across rounds — the sharded miner's bucketed
    support jobs — hit the same compiled program whenever shapes repeat,
    exactly like the single-device DataPlane's jit-cache discipline.
    """
    from jax.experimental.shard_map import shard_map

    def shard_body(x, *extra):
        v = job.map_fn(x, *extra)
        return jax.tree.map(lambda a: jax.lax.psum(a, axis), v)

    spec_out = jax.tree.map(lambda _: P(), job.zero_fn())
    f = shard_map(shard_body, mesh=mesh,
                  in_specs=(P(axis),) + (P(),) * n_extra,
                  out_specs=spec_out, check_rep=False)
    return jax.jit(f)


def run_sharded(job: MapReduceJob, data: jnp.ndarray, mesh,
                axis: str = "data", *,
                extra_args: Tuple[Any, ...] = (),
                profile: Optional[HeterogeneityProfile] = None,
                shard_costs: Optional[np.ndarray] = None,
                ) -> Tuple[Any, ExecReport]:
    """Execute map over equal shards of `data`'s leading axis; reduce with a
    psum tree.  Returns ``(result, ExecReport)`` like ``SimulatedCluster.run``
    so simulated and sharded executions are report-comparable.

    `map_fn` must be jax-traceable, take ``(shard, *extra_args)`` and return
    a pytree of arrays with shapes independent of the shard size.
    ``extra_args`` are replicated to every shard (e.g. a candidate bitmap).

    Timing: with a `profile` (and per-rank `shard_costs` in the same work
    units the scheduler uses — defaults to an equal split of
    ``data.nbytes``), busy seconds are ``cost / speed`` per rank; without a
    profile the report carries measured wall time only.  Energy and switch
    pricing live in ``repro.runtime.Runtime.run_phase`` — the one place
    every plane's accounting happens — not here.
    """
    n_shards = mesh.shape[axis]
    f = _sharded_fn(job, mesh, axis, len(extra_args))
    t0 = time.perf_counter()
    result = f(data, *extra_args)
    result = jax.block_until_ready(result)
    wall_s = time.perf_counter() - t0

    if profile is not None:
        if profile.n != n_shards:
            raise ValueError(f"profile has {profile.n} ranks but mesh axis "
                             f"{axis!r} has {n_shards}")
        if shard_costs is None:
            shard_costs = np.full(n_shards, data.nbytes / n_shards)
        shard_costs = np.asarray(shard_costs, dtype=np.float64)
        busy = shard_costs / profile.speeds
        makespan = float(busy.max()) if len(busy) else 0.0
        rep = ExecReport(makespan=makespan, busy_s=busy,
                         tiles_done=[int(c > 0) for c in shard_costs])
    else:
        rep = ExecReport(makespan=wall_s, busy_s=np.zeros(n_shards),
                         tiles_done=[1] * n_shards)
    return result, rep
