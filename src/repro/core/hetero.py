"""Heterogeneity profiles — the paper's "cores with different processing
powers" generalized to per-device effective-throughput vectors.

The paper's running example (§V) is a four-core system with processing powers
80, 120, 200 and 400 (MB/s of transaction data).  At pod scale the same
abstraction captures stragglers, multi-tenant hosts and mixed-generation
slices; throughputs are *measured* (EWMA over observed shard times) rather
than assumed.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

# The paper's §V example system.
PAPER_CORES = (80.0, 120.0, 200.0, 400.0)


@dataclass
class HeterogeneityProfile:
    """Per-device effective throughput (work units / second)."""

    speeds: np.ndarray                       # [n_devices] > 0
    names: Optional[List[str]] = None
    ewma_alpha: float = 0.3

    def __post_init__(self):
        self.speeds = np.asarray(self.speeds, dtype=np.float64)
        if (self.speeds <= 0).any():
            raise ValueError("speeds must be positive")
        if self.names is None:
            self.names = [f"core{i}" for i in range(len(self.speeds))]

    # ------------------------------------------------------------------
    @classmethod
    def paper(cls) -> "HeterogeneityProfile":
        return cls(np.array(PAPER_CORES), names=["c80", "c120", "c200", "c400"])

    @classmethod
    def homogeneous(cls, n: int, speed: float = 1.0) -> "HeterogeneityProfile":
        return cls(np.full(n, speed))

    @classmethod
    def straggler(cls, n: int, n_slow: int = 1, slowdown: float = 4.0) -> "HeterogeneityProfile":
        s = np.ones(n)
        s[:n_slow] = 1.0 / slowdown
        return cls(s)

    @classmethod
    def mixed_generation(cls, n_old: int, n_new: int, ratio: float = 2.35) -> "HeterogeneityProfile":
        """e.g. v5e (197 Tf) next to v4 (~275/3.3≈84% ... ) — ratio is new/old."""
        return cls(np.concatenate([np.ones(n_old), np.full(n_new, ratio)]))

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.speeds)

    @property
    def total_speed(self) -> float:
        return float(self.speeds.sum())

    def shares(self) -> np.ndarray:
        return self.speeds / self.speeds.sum()

    def fastest(self) -> int:
        return int(np.argmax(self.speeds))

    # ------------------------------------------------------------------
    def observe(self, device: int, work_done: float, seconds: float) -> None:
        """EWMA throughput update from a measured shard execution (the
        'dynamic' mode of the paper's core switching)."""
        if seconds <= 0:
            return
        rate = work_done / seconds
        a = self.ewma_alpha
        self.speeds[device] = (1 - a) * self.speeds[device] + a * rate

    def copy(self) -> "HeterogeneityProfile":
        return HeterogeneityProfile(self.speeds.copy(), list(self.names), self.ewma_alpha)
