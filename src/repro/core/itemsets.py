"""Bitmap Apriori — the paper's Market Basket Analysis steps 1–2 as
MapReduce rounds over a packed transaction bitmap.

Data plane (JAX / Pallas): transactions are a dense 0/1 matrix
``T ∈ uint8[n_tx, n_items]`` (item-minor, padded to 128 lanes); support of a
candidate bitmask row c is ``Σ_t 1[T_t ∧ c = c]``, computed on the MXU as
``dot(T, cᵀ) == |c|`` — see ``repro.kernels.support_count``.

Control plane (host): level-k candidate *generation* (the classic
F_{k-1}⋈F_{k-1} join + downward-closure prune) is tiny serial work — the
paper's "single-threaded task", which the MB Scheduler routes to one core
while gating the rest (power model hook).

``apriori`` below is the minimal reference driver (used by the property
tests and B1 bench); the production path with full scheduling/energy
accounting, data-plane batching and rule extraction is
``repro.pipeline.MarketBasketPipeline``, which shares this module's
candidate generation.  Behavioral changes to round semantics belong in
both, and each is pinned to the same brute-force oracle by tests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.mapreduce import FailureEvent, MapReduceJob, SimulatedCluster
from repro.core.scheduler import MBScheduler, TaskSpec


# ---------------------------------------------------------------------------
# support counting (data plane)
# ---------------------------------------------------------------------------

def support_counts_ref(T: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle.  T: [N, I] uint8 0/1; C: [M, I] uint8 0/1 -> [M] int32."""
    dots = jnp.dot(T.astype(jnp.int32), C.astype(jnp.int32).T)   # [N, M]
    sizes = C.astype(jnp.int32).sum(axis=1)                      # [M]
    return (dots == sizes[None, :]).astype(jnp.int32).sum(axis=0)


def support_counts(T, C, use_pallas: bool = False) -> jnp.ndarray:
    if use_pallas:
        from repro.kernels.support_count.ops import support_count as sc
        return sc(T, C)
    return support_counts_ref(T, C)


# ---------------------------------------------------------------------------
# candidate generation (control plane, classic Apriori)
# ---------------------------------------------------------------------------

def generate_candidates(frequent: List[Tuple[int, ...]]) -> List[Tuple[int, ...]]:
    """F_{k-1} ⋈ F_{k-1} join + downward-closure prune.  Itemsets are sorted
    tuples of item ids."""
    if not frequent:
        return []
    k = len(frequent[0]) + 1
    fset = set(frequent)
    out: List[Tuple[int, ...]] = []
    by_prefix: Dict[Tuple[int, ...], List[int]] = {}
    for t in frequent:
        by_prefix.setdefault(t[:-1], []).append(t[-1])
    for prefix, lasts in by_prefix.items():
        lasts = sorted(lasts)
        for i, a in enumerate(lasts):
            for b in lasts[i + 1:]:
                cand = prefix + (a, b)
                # prune: every (k-1)-subset must be frequent
                if all(cand[:j] + cand[j + 1:] in fset for j in range(k)):
                    out.append(cand)
    return sorted(out)


def itemsets_to_bitmap(itemsets: Sequence[Tuple[int, ...]], n_items: int) -> np.ndarray:
    C = np.zeros((len(itemsets), n_items), dtype=np.uint8)
    for i, s in enumerate(itemsets):
        C[i, list(s)] = 1
    return C


# ---------------------------------------------------------------------------
# the level-wise Apriori driver (paper §V steps 1-2)
# ---------------------------------------------------------------------------

def frequent_itemsets(supports: Dict[Tuple[int, ...], int],
                      k: Optional[int] = None) -> List[Tuple[int, ...]]:
    """Sorted frequent itemsets from a supports dict, optionally one level."""
    items = supports.keys()
    if k is not None:
        items = (s for s in items if len(s) == k)
    return sorted(items)


@dataclass
class AprioriResult:
    supports: Dict[Tuple[int, ...], int]      # itemset -> absolute support
    n_tx: int
    levels: int
    reports: list = field(default_factory=list)

    def frequent(self, k: Optional[int] = None) -> List[Tuple[int, ...]]:
        return frequent_itemsets(self.supports, k)


def _tile_rows(T: np.ndarray, n_tiles: int) -> List[np.ndarray]:
    return [np.ascontiguousarray(t) for t in np.array_split(T, n_tiles) if len(t)]


def apriori(T: np.ndarray, min_support: int, *,
            cluster: Optional[SimulatedCluster] = None,
            n_tiles: int = 8,
            max_k: int = 0,
            use_pallas: bool = False,
            failures: Optional[List[FailureEvent]] = None) -> AprioriResult:
    """Level-wise frequent-itemset mining over a transaction bitmap.

    Each level is one MapReduce round: the map phase counts candidate
    supports on row-tiles of T, the reduce phase sums the count vectors
    (a psum tree on hardware; the combiner here).  min_support is absolute.
    """
    n_tx, n_items = T.shape
    if cluster is None:
        cluster = SimulatedCluster(HeterogeneityProfile.paper())
    # hoist the tile uploads: one h2d per tile for the whole mine, and all
    # per-tile map results stay device-resident until the round's single
    # np.asarray readback below (same contract as the pipeline plane)
    tiles = [jnp.asarray(t) for t in _tile_rows(T, n_tiles)]
    supports: Dict[Tuple[int, ...], int] = {}
    reports = []

    # ---- step 1: item frequency (<item, count>) ----
    job1 = MapReduceJob(
        name="mba-step1-item-counts",
        map_fn=lambda tile: tile.sum(axis=0, dtype=jnp.int32),
        combine_fn=lambda a, b: a + b,
        zero_fn=lambda: jnp.zeros(n_items, dtype=jnp.int32),
    )
    counts, rep = cluster.run(job1, tiles, failures=failures)
    counts = np.asarray(counts, dtype=np.int64)
    reports.append(("k=1", rep))
    frequent = [(int(i),) for i in np.nonzero(counts >= min_support)[0]]
    for (i,) in frequent:
        supports[(i,)] = int(counts[i])

    # ---- step 2 loop: candidate generation + support counting ----
    k = 2
    while frequent and (max_k == 0 or k <= max_k):
        cands = generate_candidates(frequent)
        if not cands:
            break
        C = itemsets_to_bitmap(cands, n_items)
        Cj = jnp.asarray(C)

        def map_fn(tile, Cj=Cj):
            return support_counts(tile, Cj, use_pallas=use_pallas)

        job = MapReduceJob(
            name=f"mba-step2-support-k{k}",
            map_fn=map_fn,
            combine_fn=lambda a, b: a + b,
            zero_fn=lambda m=len(cands): jnp.zeros(m, dtype=jnp.int32),
        )
        sup, rep = cluster.run(job, tiles, failures=failures)
        sup = np.asarray(sup, dtype=np.int64)   # the round's one readback
        reports.append((f"k={k}", rep))
        frequent = []
        for c, s in zip(cands, sup):
            if s >= min_support:
                supports[c] = int(s)
                frequent.append(c)
        k += 1

    return AprioriResult(supports=supports, n_tx=n_tx, levels=k - 1,
                         reports=reports)


# ---------------------------------------------------------------------------
# brute-force oracle for tests
# ---------------------------------------------------------------------------

def apriori_bruteforce(T: np.ndarray, min_support: int, max_k: int = 4) -> Dict[Tuple[int, ...], int]:
    n_tx, n_items = T.shape
    out: Dict[Tuple[int, ...], int] = {}
    frequent_items = [i for i in range(n_items) if T[:, i].sum() >= min_support]
    for k in range(1, max_k + 1):
        any_f = False
        for comb in itertools.combinations(frequent_items, k):
            s = int(np.all(T[:, list(comb)] == 1, axis=1).sum())
            if s >= min_support:
                out[tuple(comb)] = s
                any_f = True
        if not any_f:
            break
    return out
