"""Overlap-friendly collective schedules (shard_map building blocks).

GSPMD inserts collectives automatically in the jit path; these explicit
versions exist for (a) the compressed-DP train step, (b) tests that pin the
exact schedule, and (c) the §Perf experiments that compare an XLA-chosen
all-gather against a ring schedule that overlaps with compute.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size as _axis_size


def ring_all_gather(x: jnp.ndarray, axis_name: str,
                    compute: Optional[Callable[[jnp.ndarray, int], None]] = None
                    ) -> jnp.ndarray:
    """All-gather along `axis_name` via N-1 ppermute hops (bi-section-friendly
    ring).  If `compute` is given it is called with each arriving shard —
    the overlap hook: on hardware each hop's DMA runs concurrently with
    consuming the previous shard."""
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    shards = [x]
    cur = x
    for hop in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        shards.append(cur)
    # device i received shards in order i, i-1, i-2, ... — rotate to global order
    stacked = jnp.stack(shards)                        # [n, ...] local order
    offsets = (idx - jnp.arange(n)) % n                # global slot of each entry
    out = jnp.zeros_like(stacked)
    out = out.at[offsets].set(stacked)
    return out.reshape((-1,) + x.shape[1:]) if x.ndim else out


def reduce_scatter_sum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """psum_scatter along leading dim."""
    return jax.lax.psum_scatter(x, axis_name, scatter_dimension=0, tiled=True)


def hierarchical_psum(x: jnp.ndarray, inner: str, outer: Optional[str]) -> jnp.ndarray:
    """Two-level gradient sum: reduce inside a pod first (fast ICI), then
    across pods (slower DCN) — the multi-pod schedule verified in the
    dry-run HLO."""
    x = jax.lax.psum(x, inner)
    if outer is not None:
        x = jax.lax.psum(x, outer)
    return x
