"""Fault-tolerance policy layer: failure events, restart decisions, straggler
detection, elastic resize plans.

The training loop (launch/train.py) consults a :class:`RestartPolicy` every
step; failures in this container are *injected* (no real hardware faults),
which exercises exactly the code paths a pod deployment runs: detect →
checkpoint-restore → (optionally) shrink the device set → re-plan shards via
the MB scheduler → continue.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.data.sharding import BatchPlan, plan_batches


@dataclass
class FaultEvent:
    step: int
    kind: str                  # "device_loss" | "straggler" | "preemption"
    device: int
    severity: float = 1.0      # straggler slowdown factor


@dataclass
class FaultPlan:
    """Scripted fault injection for tests/examples."""
    events: List[FaultEvent] = field(default_factory=list)

    def at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]


@dataclass
class RestartPolicy:
    max_restarts: int = 3
    checkpoint_every: int = 50
    straggler_threshold: float = 2.0   # ×median step time → re-plan
    elastic: bool = True               # shrink vs abort on device loss

    restarts_used: int = 0

    def on_device_loss(self, profile: HeterogeneityProfile,
                       device: int) -> Optional[HeterogeneityProfile]:
        """Returns the shrunken profile (elastic) or None (abort+restart)."""
        self.restarts_used += 1
        if self.restarts_used > self.max_restarts:
            raise RuntimeError("restart budget exhausted")
        if not self.elastic:
            return None
        speeds = np.delete(profile.speeds, device)
        names = [n for i, n in enumerate(profile.names) if i != device]
        return HeterogeneityProfile(speeds, names, profile.ewma_alpha)

    def on_straggler(self, profile: HeterogeneityProfile, device: int,
                     slowdown: float) -> HeterogeneityProfile:
        """EWMA the slowdown into the profile → the next re-plan gives the
        straggler proportionally less work (paper: dynamic core switching)."""
        p = profile.copy()
        p.observe(device, work_done=1.0, seconds=slowdown)
        return p


def detect_stragglers(step_times: np.ndarray, threshold: float = 2.0) -> List[int]:
    """Indices of devices whose step time exceeds threshold × median."""
    med = float(np.median(step_times))
    return [int(i) for i in np.nonzero(step_times > threshold * med)[0]]
