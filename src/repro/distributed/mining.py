"""Distributed mining plane — shard_map Apriori over a heterogeneous mesh.

The single-device pipeline *simulates* the paper's cluster; this module
*executes* it: the packed transaction bitmap is partitioned across a
data-parallel mesh axis, `support_count` runs per shard inside `shard_map`
as the map phase, and partial support vectors reduce through the psum
combiner tree in :func:`repro.core.mapreduce.run_sharded`.

Heterogeneity shows up as shard *composition*, not shard shape: every rank
owns one static ``[width, n_items]`` slab (a jit-cache requirement), but the
number of *real* transaction rows inside it is planned ∝ core speed by
:func:`repro.data.sharding.plan_shard_rows` — padding rows are all-zero and
therefore inert for support counting.  A failure (``device_loss``) or
straggler observation re-plans that integer vector mid-mine (the paper's
dynamic core switching): the dead rank's slab becomes pure padding (gated
watts in the power model) and its row blocks re-issue to survivors, with
the move counts surfaced in the :class:`PipelineReport`.

Scheduling and accounting run on the shared :class:`repro.runtime.Runtime`:
the shard layout is handed to ``run_phase`` as a *pinned* assignment (rank
d owns tile d with its planned row bytes), shard-re-plan moves are charged
as this phase's switches/re-issues, and time/energy come off the same
ledger the simulated and serving planes use.  Serial phases (candidate
generation, rule extraction) run host-side on the driver process, which is
co-located with mesh rank 0 — they are routed there via
``Runtime.run_serial(device=0)``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.hetero import HeterogeneityProfile
from repro.core.mapreduce import MapReduceJob, run_sharded
from repro.core.itemsets import (AprioriResult, generate_candidates,
                                 itemsets_to_bitmap)
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.data.sharding import plan_shard_rows
from repro.data.sparse import SparseSlab, density_stats
from repro.distributed.fault import FaultPlan
from repro.kernels.support_count.ref import support_count_ref
from repro.pipeline.dataplane import pad_candidates, resolve_backend
from repro.pipeline.pipeline import (Baskets, PipelineConfig, PipelineResult,
                                     ingest_baskets)
from repro.pipeline.report import PipelineReport, RoundReport
from repro.runtime import (MeasuredPhase, Runtime, SwitchingPolicy,
                           autotuned_costmodel)
from repro.core.rules import generate_rules

DEFAULT_AXIS = "shards"


# ---------------------------------------------------------------------------
# mesh + profile helpers
# ---------------------------------------------------------------------------

def make_shard_mesh(n_shards: Optional[int] = None,
                    axis: str = DEFAULT_AXIS) -> Mesh:
    """1-D mesh over the first `n_shards` local devices (default: all)."""
    devs = jax.devices()
    n = n_shards or len(devs)
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_shards={n} but only {len(devs)} devices visible "
                         "(set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N for simulated multi-device CPU meshes)")
    return Mesh(np.asarray(devs[:n]), (axis,))


def mesh_profile(n: int,
                 base: Optional[HeterogeneityProfile] = None
                 ) -> HeterogeneityProfile:
    """Cycle a base profile's speeds (default: the paper's 80/120/200/400)
    out to an n-rank mesh — the paper's core mix at pod scale."""
    base = base or HeterogeneityProfile.paper()
    speeds = np.resize(base.speeds, n)
    names = [f"{base.names[i % base.n]}.{i // base.n}" for i in range(n)]
    return HeterogeneityProfile(speeds, names=names,
                                ewma_alpha=base.ewma_alpha)


def partition_miner(mesh: Optional[Mesh] = None,
                    config: Optional[PipelineConfig] = None,
                    base_profile: Optional[HeterogeneityProfile] = None,
                    policy: Union[str, "SwitchingPolicy", None] = None,
                    row_block: int = 8,
                    verify_rounds: bool = False) -> "ShardedMiner":
    """Per-partition entry point for the SON out-of-core plane: one
    :class:`ShardedMiner` sized to ``mesh`` (profile cycled from
    ``base_profile``) that the SON driver reuses across every partition
    sharing a local config — so the compiled shard_map programs and the
    shard planner's jit caches are built once, not once per partition.
    ``config.algorithm`` must already be resolved (SON decides ``auto``
    once, globally, before the first partition)."""
    mesh = mesh if mesh is not None else make_shard_mesh()
    n = mesh.shape[mesh.axis_names[0]]
    return ShardedMiner(mesh=mesh, profile=mesh_profile(n, base_profile),
                        config=config, policy=policy, row_block=row_block,
                        verify_rounds=verify_rounds)


# ---------------------------------------------------------------------------
# shard planning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardPlan:
    """Static-shape shard layout: rank d owns rows[d] real rows inside a
    zero-padded ``[width, n_items]`` slab."""

    rows: np.ndarray          # [n_shards] real rows per rank (row_block ·)
    width: int                # padded rows per shard (static, = max rows)
    row_block: int
    alive: np.ndarray         # [n_shards] bool

    @property
    def n_shards(self) -> int:
        return len(self.rows)

    @property
    def n_blocks(self) -> int:
        return int(self.rows.sum()) // self.row_block

    def block_owners(self) -> np.ndarray:
        """owner rank of each row block, in global block order (blocks are
        assigned contiguously, so a re-plan is comparable block-by-block)."""
        return np.repeat(np.arange(self.n_shards),
                         self.rows // self.row_block)

    def shard_costs(self, n_items: int) -> np.ndarray:
        """Per-rank work units (bytes of *real* transaction data) — the same
        units the simulated pipeline's tile costs use."""
        return self.rows.astype(np.float64) * n_items


def plan_shards(profile: HeterogeneityProfile, n_rows: int,
                row_block: int = 8,
                alive: Optional[np.ndarray] = None) -> ShardPlan:
    """Heterogeneity-aware shard plan over the alive ranks."""
    alive = (np.ones(profile.n, dtype=bool) if alive is None
             else np.asarray(alive, dtype=bool))
    rows = plan_shard_rows(profile, n_rows, row_block=row_block, alive=alive)
    width = int(rows.max())
    return ShardPlan(rows=rows, width=width, row_block=row_block,
                     alive=alive.copy())


def shard_bitmap(T: np.ndarray, plan: ShardPlan) -> np.ndarray:
    """Lay T out rank-major per the plan: rank d's slab holds its contiguous
    row range zero-padded to `width`.  Shape [n_shards * width, n_items]."""
    n_tx, n_items = T.shape
    out = np.zeros((plan.n_shards * plan.width, n_items), dtype=T.dtype)
    start = 0
    for d in range(plan.n_shards):
        r = min(int(plan.rows[d]), max(n_tx - start, 0))
        out[d * plan.width:d * plan.width + r] = T[start:start + r]
        start += int(plan.rows[d])
    return out


def count_moves(old: ShardPlan, new: ShardPlan) -> Tuple[int, int]:
    """(switches, reissued) between two plans over the same bitmap:
    `switches` = row blocks that changed owner between two live ranks,
    `reissued` = row blocks re-issued away from a rank that died."""
    a, b = old.block_owners(), new.block_owners()
    assert len(a) == len(b), "plans cover different bitmaps"
    moved = a != b
    from_dead = moved & ~new.alive[a]
    return int((moved & ~from_dead).sum()), int(from_dead.sum())


# ---------------------------------------------------------------------------
# jax-traceable map bodies (module-level: stable identities keep the
# run_sharded program cache warm across rounds and runs)
# ---------------------------------------------------------------------------

def _item_counts_map(shard):
    return shard.sum(axis=0, dtype=jnp.int32)


def _support_map_ref(shard, C):
    return support_count_ref(shard, C)


def _support_map_pallas(shard, C):
    from repro.kernels.support_count.ops import support_count
    return support_count(shard, C)


def _eclat_item_counts_map(shard):
    """shard: [width, n_items] word-major packed tid matrix (uint32) —
    per-item counts are plain column popcount sums; padding words are 0."""
    return jnp.sum(jax.lax.population_count(shard).astype(jnp.int32), axis=0)


def _eclat_support_map(shard, Cidx):
    """Stateless k-way AND over base item columns, per shard.

    ``Cidx [M, k] int32`` holds each candidate's item ids.  Unlike the
    single-device Eclat plane's pairwise (k-1)-slab cascade, the sharded
    round recomputes each candidate's tidset from the *base* columns —
    carrying per-rank intermediate slabs through shard re-plans would
    couple the fault path to mining state; k is small (≤ a handful of
    levels) so the extra ANDs are cheap and every round stays a pure
    function of (data, Cidx).  Both formulations count identical bits.
    """
    g = jnp.take(shard, Cidx[:, 0], axis=1)            # [width, M]
    for j in range(1, Cidx.shape[1]):                  # k is static
        g = g & jnp.take(shard, Cidx[:, j], axis=1)
    return jnp.sum(jax.lax.population_count(g).astype(jnp.int32), axis=0)


# ---------------------------------------------------------------------------
# the miner
# ---------------------------------------------------------------------------

class ShardedMiner:
    """MarketBasketPipeline semantics, executed over a real device mesh.

    Produces the same ``PipelineResult`` (bit-identical supports and rules —
    tested against the single-device plane) with a report whose map phases
    were *executed* under shard_map + psum rather than event-simulated.
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 profile: Optional[HeterogeneityProfile] = None,
                 config: Optional[PipelineConfig] = None,
                 scheduler: Optional[MBScheduler] = None,
                 power: Optional[PowerModel] = None,
                 policy: Union[str, SwitchingPolicy, None] = None,
                 row_block: int = 8,
                 verify_rounds: bool = False):
        self.mesh = mesh if mesh is not None else make_shard_mesh()
        self.axis = self.mesh.axis_names[0]
        n = self.mesh.shape[self.axis]
        self.profile = profile or mesh_profile(n)
        if self.profile.n != n:
            raise ValueError(f"profile has {self.profile.n} ranks but mesh "
                             f"axis {self.axis!r} has {n}")
        self.config = config or PipelineConfig()
        policy = policy if policy is not None else self.config.policy
        if policy == "costmodel" and self.config.autotune:
            # measured kernel walls replace the datasheet constants (the
            # kernel the chosen formulation actually dispatches to)
            policy = autotuned_costmodel(
                "intersect_count" if self.config.algorithm == "eclat"
                else "support_count")
        self.runtime = Runtime(
            self.profile,
            policy=policy,
            split=self.config.split,
            power=power if power is not None else self.config.power,
            scheduler=scheduler)
        self.scheduler = self.runtime.scheduler
        self.power = self.runtime.power
        self.backend = resolve_backend(self.config.data_plane)
        self.row_block = row_block
        self.verify_rounds = verify_rounds
        # stable job objects -> run_sharded's compiled-program cache hits
        # whenever a later round (or run) repeats a batch shape
        self._item_jobs: dict = {}
        self._support_jobs: dict = {}
        self._eclat_jobs: dict = {}
        # the auto-selector's decision for the last run() (None when the
        # algorithm was explicit) — the CLI surfaces it
        self.algorithm_choice = None

    # ------------------------------------------------------------------
    def _item_job(self, n_items: int) -> MapReduceJob:
        job = self._item_jobs.get(n_items)
        if job is None:
            job = MapReduceJob(
                name=f"sharded-round1-item-counts-{n_items}",
                map_fn=_item_counts_map,
                combine_fn=lambda a, b: a + b,
                zero_fn=lambda m=n_items: jnp.zeros(m, jnp.int32))
            self._item_jobs[n_items] = job
        return job

    def _support_job(self, m_padded: int) -> MapReduceJob:
        job = self._support_jobs.get(m_padded)
        if job is None:
            map_fn = (_support_map_pallas if self.backend == "pallas"
                      else _support_map_ref)
            job = MapReduceJob(
                name=f"sharded-support-m{m_padded}",
                map_fn=map_fn,
                combine_fn=lambda a, b: a + b,
                zero_fn=lambda m=m_padded: jnp.zeros(m, jnp.int32))
            self._support_jobs[m_padded] = job
        return job

    def _eclat_job(self, m_padded: int, k: int) -> MapReduceJob:
        """One job per (candidate bucket, level arity): the k-way AND body
        specializes on Cidx's static column count."""
        job = self._eclat_jobs.get((m_padded, k))
        if job is None:
            job = MapReduceJob(
                name=f"eclat-sharded-intersect-m{m_padded}-k{k}",
                map_fn=(_eclat_item_counts_map if k == 1
                        else _eclat_support_map),
                combine_fn=lambda a, b: a + b,
                zero_fn=lambda m=m_padded: jnp.zeros(m, jnp.int32))
            self._eclat_jobs[(m_padded, k)] = job
        return job

    # ------------------------------------------------------------------
    def _sharded_round(self, job: MapReduceJob, data: jnp.ndarray,
                       plan: ShardPlan, n_items: int,
                       extra_args: Tuple = (),
                       switches: int = 0, reissued: int = 0):
        """One shard_map round through the shared runtime.  The shard plan
        *is* the assignment (rank d owns tile d, cost = its real-row bytes);
        re-plan moves are charged to this phase; busy/energy are modeled on
        the ledger exactly as for the other planes."""
        costs = plan.shard_costs(n_items)
        task = TaskSpec(job.name, float(costs.sum()), parallel=True,
                        n_tiles=self.profile.n)

        def execute(_asg, _costs):
            result, rep = run_sharded(job, data, self.mesh, self.axis,
                                      extra_args=extra_args)
            # the psum-reduced vector comes back host-side here, inside the
            # phase, so the round's single sync lands on this map record
            result = self.runtime.meter.d2h(result, dtype=np.int64)
            return MeasuredPhase(result=result, wall_s=rep.makespan)

        return self.runtime.run_phase(
            task, execute, tile_costs=costs,
            assignment=self.runtime.pinned_assignment(costs),
            extra_switches=switches, extra_reissued=reissued)

    def _serial(self, name: str, cost: float, fn=None):
        # driver phases execute on the host co-located with rank 0
        return self.runtime.run_serial(name, cost, fn=fn, device=0)

    # ------------------------------------------------------------------
    def _apply_faults(self, k: int, faults: Optional[FaultPlan],
                      alive: np.ndarray, plan: ShardPlan, T: np.ndarray,
                      report: PipelineReport,
                      row_block: Optional[int] = None
                      ) -> Tuple[ShardPlan, Optional[jnp.ndarray],
                                 int, int, List[int]]:
        """Consume round-k fault events; returns the (possibly new) plan,
        re-laid-out device data (or None if unchanged), and this round's
        (switches, reissued, newly_dead).  ``T`` is whatever row matrix
        the plane shards (transaction rows for Apriori, packed tid words
        for Eclat — ``row_block`` overrides the transaction-row blocking
        for the latter, where one row already covers 32 transactions)."""
        row_block = self.row_block if row_block is None else row_block
        events = faults.at(k) if faults else []
        newly_dead: List[int] = []
        replan = False
        for e in events:
            if e.kind == "device_loss" and alive[e.device]:
                alive[e.device] = False
                newly_dead.append(e.device)
                replan = True
            elif e.kind == "straggler":
                # observed rate = current speed / slowdown, EWMA'd into the
                # profile -> the re-plan gives the straggler proportionally
                # fewer row blocks (severity 1.0 = no slowdown, no change)
                self.profile.observe(
                    e.device,
                    work_done=float(self.profile.speeds[e.device]),
                    seconds=float(e.severity))
                replan = True
        if not replan:
            return plan, None, 0, 0, newly_dead
        new_plan = plan_shards(self.profile, T.shape[0],
                               row_block=row_block, alive=alive)
        switches, reissued = count_moves(plan, new_plan)
        self.scheduler.switches += switches + reissued
        report.replans += 1
        report.shard_rows = [int(r) for r in new_plan.rows]
        return (new_plan, self.runtime.meter.h2d(shard_bitmap(T, new_plan)),
                switches, reissued, newly_dead)

    def _check_round(self, k: int, T: np.ndarray, C_padded: Optional[np.ndarray],
                     counts: np.ndarray) -> None:
        """Cross-shard invariant: the psum-reduced global support vector must
        equal the single-device oracle on the unsharded bitmap."""
        if C_padded is None:                       # k=1 column sums
            want = T.sum(axis=0, dtype=np.int64)[:len(counts)]
        else:
            want = np.asarray(support_count_ref(
                jnp.asarray(T), jnp.asarray(C_padded)),
                dtype=np.int64)[:len(counts)]
        if not np.array_equal(counts, want):
            bad = int(np.flatnonzero(counts != want)[0])
            raise RuntimeError(
                f"cross-shard invariant violated at round k={k}: "
                f"candidate {bad} counted {counts[bad]} sharded vs "
                f"{want[bad]} single-device")

    # ------------------------------------------------------------------
    @staticmethod
    def _round_view(rec, plan: ShardPlan, k: int, n_candidates: int,
                    n_frequent: int, dead: List[int],
                    serial=None, m_padded: int = 0) -> RoundReport:
        """Per-round view with shard-plan tile semantics: "tiles" are row
        blocks (Σ blocks == n_tiles invariant), not the per-rank slabs the
        pinned assignment schedules."""
        return RoundReport(
            k=k, n_candidates=n_candidates, n_frequent=n_frequent,
            n_tiles=plan.n_blocks,
            tiles_per_device=[int(b) for b in plan.rows // plan.row_block],
            map_makespan_s=rec.sim_time_s, map_busy_s=list(rec.busy_s),
            switches=rec.switches, reissued=rec.reissued,
            energy_j=rec.energy_j, serial=serial, m_padded=m_padded,
            failed_devices=dead)

    def run(self, baskets: Baskets,
            faults: Optional[FaultPlan] = None) -> PipelineResult:
        """Dispatch on ``config.algorithm`` (apriori | eclat | auto) —
        every formulation produces bit-identical supports and rules."""
        algorithm = self.config.algorithm
        self.algorithm_choice = None
        if algorithm == "auto":
            from repro.mining.select import select_algorithm
            stats = density_stats(baskets)
            self.algorithm_choice = select_algorithm(
                baskets, self.config.abs_support(stats.n_tx), stats=stats)
            algorithm = self.algorithm_choice.algorithm
        if algorithm == "eclat":
            return self._run_eclat(baskets, faults)
        if algorithm != "apriori":
            raise ValueError(f"unknown mining algorithm {algorithm!r}")
        return self._run_apriori(baskets, faults)

    def _run_apriori(self, baskets: Baskets,
                     faults: Optional[FaultPlan] = None) -> PipelineResult:
        cfg = self.config
        rt = self.runtime
        t_start = time.perf_counter()
        # a run that raised mid-way (invariant check, scoring error) leaves
        # orphaned records; this plane owns its runtime, so anything still
        # live belongs to no report — drop it before marking
        rt.ledger.take_since(0)
        mark = rt.ledger.mark()

        T, n_items_raw, n_tx_raw = ingest_baskets(baskets)
        n_tx, n_items = T.shape                    # lane-padded (internal)
        min_sup = cfg.abs_support(n_tx_raw)
        n = self.profile.n

        alive = np.ones(n, dtype=bool)
        plan = plan_shards(self.profile, n_tx, row_block=self.row_block,
                           alive=alive)
        data = rt.meter.h2d(shard_bitmap(T, plan))

        report = PipelineReport(
            backend=self.backend, policy=rt.policy.name, split=rt.split,
            profile_speeds=[float(s) for s in self.profile.speeds],
            n_tx=n_tx_raw, n_items=n_items_raw,
            n_tiles=plan.n_blocks, min_support=min_sup,
            execution="sharded", n_shards=n,
            shard_rows=[int(r) for r in plan.rows])
        supports = {}

        # ---- round k=1: item frequency (<item, count>) ----------------
        plan, new_data, sw, re, dead = self._apply_faults(
            1, faults, alive, plan, T, report)
        if new_data is not None:
            data = new_data
        counts, rec = self._sharded_round(
            self._item_job(n_items), data, plan, n_items,
            switches=sw, reissued=re)
        if self.verify_rounds:
            self._check_round(1, T, None, counts)
        frequent = [(int(i),) for i in np.nonzero(
            counts[:n_items_raw] >= min_sup)[0]]
        for (i,) in frequent:
            supports[(i,)] = int(counts[i])
        report.rounds.append(self._round_view(
            rec, plan, k=1, n_candidates=n_items_raw,
            n_frequent=len(frequent), dead=dead))

        # ---- rounds k>=2: serial candidate-gen + sharded counting -----
        k = 2
        while frequent and (cfg.max_k == 0 or k <= cfg.max_k):
            plan, new_data, sw, re, dead = self._apply_faults(
                k, faults, alive, plan, T, report)
            if new_data is not None:
                data = new_data
            cands, serial = self._serial(
                f"mba-candgen-k{k}",
                cost=max(1.0, len(frequent) * k * cfg.serial_unit_cost),
                fn=lambda fr=frequent: generate_candidates(fr))
            if not cands:
                # a replan consumed this round but no map phase will run to
                # carry its moves: charge them (counts AND joules) to the
                # serial record so the ledger still accounts every
                # migration exactly once
                rt.charge_moves(serial, sw, re)
                view = RoundReport.from_phases(
                    k=k, n_candidates=0, n_frequent=0, map_phase=None,
                    serial=serial, n_devices=n)
                view.switches, view.reissued = sw, re
                view.failed_devices = dead
                report.rounds.append(view)
                break

            C = pad_candidates(itemsets_to_bitmap(cands, n_items),
                               cfg.m_bucket)
            Cj = rt.meter.h2d(C)
            sup_all, rec = self._sharded_round(
                self._support_job(C.shape[0]), data, plan, n_items,
                extra_args=(Cj,), switches=sw, reissued=re)
            # padded candidate rows are all-zero masks and would match every
            # transaction — slice to the true count, never trust padding
            sup = sup_all[:len(cands)]
            if self.verify_rounds:
                self._check_round(k, T, C, sup)
            frequent = []
            for c, s in zip(cands, sup):
                if s >= min_sup:
                    supports[c] = int(s)
                    frequent.append(c)
            report.rounds.append(self._round_view(
                rec, plan, k=k, n_candidates=len(cands),
                n_frequent=len(frequent), dead=dead, serial=serial,
                m_padded=int(C.shape[0])))
            k += 1

        # ---- step 3: association rules (driver, rank 0) ---------------
        rules, rules_rec = self._serial(
            "mba-rules",
            cost=max(1.0, len(supports) * cfg.serial_unit_cost),
            fn=lambda: generate_rules(
                AprioriResult(supports=supports, n_tx=n_tx_raw, levels=k - 1),
                cfg.min_confidence, min_lift=cfg.min_lift))
        report.rules_phase = rules_rec

        report.n_itemsets = len(supports)
        report.n_rules = len(rules)
        report.wall_time_s = time.perf_counter() - t_start
        report.ledger = rt.ledger.take_since(mark)
        return PipelineResult(supports=supports, rules=rules, report=report,
                              n_tx=n_tx_raw)

    # ------------------------------------------------------------------
    # vertical (Eclat) execution: the packed tid matrix sharded over the
    # WORD axis — each rank owns a contiguous band of 32-transaction word
    # rows, every round is a stateless k-way AND over base item columns
    # ------------------------------------------------------------------
    def _run_eclat(self, baskets: Baskets,
                   faults: Optional[FaultPlan] = None) -> PipelineResult:
        cfg = self.config
        rt = self.runtime
        t_start = time.perf_counter()
        rt.ledger.take_since(0)
        mark = rt.ledger.mark()
        n = self.profile.n

        # ---- columnize on the driver (rank 0), then shard word-major ---
        def columnize():
            if isinstance(baskets, SparseSlab):
                return (baskets.tid_columns(), baskets.n_items,
                        baskets.n_tx)
            from repro.data.sparse import pack_tid_columns
            T, ni, ntx = ingest_baskets(baskets)
            return pack_tid_columns(T), ni, ntx

        stats = density_stats(baskets)
        (cols, n_items_raw, n_tx_raw), _ = self._serial(
            "eclat-columnize", cost=max(1.0, 4.0 * stats.nnz), fn=columnize)
        min_sup = cfg.abs_support(n_tx_raw)
        n_items_pad = cols.shape[0]
        # word-major [W_pad, n_items_pad]: the shardable leading axis is
        # words (32 tx each); one "row block" is one word row
        Tw = np.ascontiguousarray(cols.T)
        # the smoke path re-counts every round against the dense oracle;
        # only then is the dense bitmap ever materialized on this plane
        T_dense = (ingest_baskets(baskets)[0] if self.verify_rounds
                   else None)

        alive = np.ones(n, dtype=bool)
        plan = plan_shards(self.profile, Tw.shape[0], row_block=1,
                           alive=alive)
        data = rt.meter.h2d(shard_bitmap(Tw, plan))
        word_bytes = 4 * n_items_pad              # cost units: real-row bytes

        report = PipelineReport(
            backend=self.backend, policy=rt.policy.name,
            algorithm="eclat", split=rt.split,
            profile_speeds=[float(s) for s in self.profile.speeds],
            n_tx=n_tx_raw, n_items=n_items_raw,
            n_tiles=plan.n_blocks, min_support=min_sup,
            execution="sharded", n_shards=n,
            shard_rows=[int(r) for r in plan.rows])
        supports = {}

        # ---- round k=1: per-item column popcounts ----------------------
        plan, new_data, sw, re, dead = self._apply_faults(
            1, faults, alive, plan, Tw, report, row_block=1)
        if new_data is not None:
            data = new_data
        counts, rec = self._sharded_round(
            self._eclat_job(n_items_pad, 1), data, plan, word_bytes,
            switches=sw, reissued=re)
        if self.verify_rounds:
            self._check_round(1, T_dense, None, counts[:n_items_raw])
        frequent = [(int(i),) for i in np.nonzero(
            counts[:n_items_raw] >= min_sup)[0]]
        for (i,) in frequent:
            supports[(i,)] = int(counts[i])
        report.rounds.append(self._round_view(
            rec, plan, k=1, n_candidates=n_items_raw,
            n_frequent=len(frequent), dead=dead))

        # ---- rounds k>=2: serial join + sharded k-way AND-popcount -----
        k = 2
        while frequent and (cfg.max_k == 0 or k <= cfg.max_k):
            plan, new_data, sw, re, dead = self._apply_faults(
                k, faults, alive, plan, Tw, report, row_block=1)
            if new_data is not None:
                data = new_data
            cands, serial = self._serial(
                f"eclat-candgen-k{k}",
                cost=max(1.0, len(frequent) * k * cfg.serial_unit_cost),
                fn=lambda fr=frequent: generate_candidates(fr))
            if not cands:
                rt.charge_moves(serial, sw, re)
                view = RoundReport.from_phases(
                    k=k, n_candidates=0, n_frequent=0, map_phase=None,
                    serial=serial, n_devices=n)
                view.switches, view.reissued = sw, re
                view.failed_devices = dead
                report.rounds.append(view)
                break

            # candidate item-id matrix, zero-padded to the bucket shape
            # (padding rows AND item 0's column with itself — junk counts
            # that are sliced away, never trusted)
            Cidx = np.zeros((-(-len(cands) // cfg.m_bucket) * cfg.m_bucket,
                             k), dtype=np.int32)
            Cidx[:len(cands)] = np.asarray(cands, dtype=np.int32)
            sup_all, rec = self._sharded_round(
                self._eclat_job(Cidx.shape[0], k), data, plan, word_bytes,
                extra_args=(rt.meter.h2d(Cidx),), switches=sw, reissued=re)
            sup = sup_all[:len(cands)]
            if self.verify_rounds:
                self._check_round(
                    k, T_dense,
                    itemsets_to_bitmap(cands, T_dense.shape[1]), sup)
            frequent = []
            for c, s in zip(cands, sup):
                if s >= min_sup:
                    supports[c] = int(s)
                    frequent.append(c)
            report.rounds.append(self._round_view(
                rec, plan, k=k, n_candidates=len(cands),
                n_frequent=len(frequent), dead=dead, serial=serial,
                m_padded=int(Cidx.shape[0])))
            k += 1

        # ---- association rules (driver, rank 0) ------------------------
        rules, rules_rec = self._serial(
            "mba-rules",
            cost=max(1.0, len(supports) * cfg.serial_unit_cost),
            fn=lambda: generate_rules(
                AprioriResult(supports=supports, n_tx=n_tx_raw, levels=k - 1),
                cfg.min_confidence, min_lift=cfg.min_lift))
        report.rules_phase = rules_rec

        report.n_itemsets = len(supports)
        report.n_rules = len(rules)
        report.wall_time_s = time.perf_counter() - t_start
        report.ledger = rt.ledger.take_since(mark)
        return PipelineResult(supports=supports, rules=rules, report=report,
                              n_tx=n_tx_raw)
