"""Mesh + named-sharding rules for every arch / pytree in the framework.

Rules are name/path-based over the parameter pytree (leading layer-stack dim
is handled by rank offset).  Every rule is divisibility-checked against the
mesh — an axis that does not divide the dim is dropped (replicated) rather
than crashing, so one rules table serves vocab sizes like 49155 and head
counts like 25.

Sharding scheme (DESIGN.md §5):
  embeddings   vocab on "model" (fallback d_model)
  attention    col-sharded qkv, row-sharded o ("model" = TP axis)
  MLP          megatron col→row
  MoE          experts on "model" (EP); fsdp adds "data" on d_ff/d_model
  SSM/RWKV     channel/head-sharded on "model" (state stays device-local)
  batch        ("pod", "data")
  optimizer    param spec + ZeRO-1 over "data" on the first free dim
  KV caches    batch on ("pod","data"), sequence on "model"
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# archs whose dense weights exceed one chip's HBM under pure TP -> shard
# weights over "data" too (FSDP / ZeRO-3 style; gathered per-layer inside
# the scan).  MoE archs instead use expert-parallelism over "data"
# (E@data × TP@model within each expert), so none currently need FSDP.
FSDP_ARCHS: tuple = ()


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    sizes = [mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]
    return dim % int(np.prod(sizes)) == 0


def _checked(spec_tail, shape, mesh: Mesh) -> P:
    """Right-align spec_tail on shape; drop non-dividing axes; pad with None."""
    n = len(shape)
    tail = list(spec_tail)[-n:]
    full = [None] * (n - len(tail)) + tail
    out = []
    for dim, ax in zip(shape, full):
        out.append(ax if (ax is not None and _fits(dim, mesh, ax)) else None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_COL = ("wq", "wk", "wv", "wg", "w_gate", "w_up", "in_proj", "dt_proj",
        "wq_a", "wq_b", "wkv_b", "wr", "proj")
_ROW = ("wo", "w_down", "out_proj", "x_proj")
_REP = ("wkv_a", "router", "mix_w1", "mix_w2", "w_lora1", "w_lora2",
        "mu_base", "mu_k", "mu_r", "w_base", "ln_scale", "scale", "dt_bias")


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool, tied: bool = False) -> P:
    """FSDP note: "data" is stacked on the SAME dim as "model" (a
    ("data","model") tuple → pure N-way weight sharding, gathered per layer
    inside the scan).  Sharding "data" on the *opposite* dim conflicts with
    the batch's data sharding and makes GSPMD replicate activations — found
    via the buffer-assignment dump (EXPERIMENTS.md §Perf iteration 0)."""
    name = path.split("/")[-1]
    in_moe = "/moe/" in path and "/shared/" not in path

    def tp(dim_idx_from_right: int, spec_tail):
        """spec_tail with ("data","model") fused on the model dim if fsdp."""
        if not fsdp or "data" not in mesh.axis_names:
            return _checked(spec_tail, shape, mesh)
        fused = tuple(("data", "model") if ax == "model" else ax
                      for ax in spec_tail)
        cand = _checked(fused, shape, mesh)
        # if the fused axis didn't divide, fall back to model-only
        if any(isinstance(ax, tuple) for ax in cand):
            return cand
        return _checked(spec_tail, shape, mesh)

    if name in ("embed", "lm_head"):
        V, d = shape[-2], shape[-1]
        # lm_head (and tied embeddings): vocab on "model" → [T@data, V@model]
        # logits.  Untied input embed: d on "model" — a vocab-sharded gather
        # backward scatters a replicated f32 [V, d] grad (buffer dump, §Perf).
        if name == "lm_head":
            if _fits(V, mesh, "model"):
                return _checked((None, "model", None), shape, mesh)
            return _checked((None, None, "model"), shape, mesh)
        # input embed: prefer d-shard — EXCEPT tied archs, whose logits
        # lower from the same table (vocab-shard wins there: a d-sharded
        # contraction would all-reduce replicated [T, V] logits).
        if tied and _fits(V, mesh, "model"):
            return _checked((None, "model", None), shape, mesh)
        if _fits(d, mesh, "model"):
            return _checked((None, None, "model"), shape, mesh)
        if _fits(V, mesh, "model"):
            return _checked((None, "model", None), shape, mesh)
        return P(*([None] * len(shape)))
    if name in ("codebook_embed", "codebook_head"):
        # EnCodec codebooks are tiny (2048×d) — replicate
        return P(*([None] * len(shape)))
    if name == "u":                                   # rwkv bonus [L,H,n]
        return _checked((None, "model", None), shape, mesh)
    if name in ("A_log", "conv_w"):                   # [..., di, N] / [...,K,di]
        if name == "A_log":
            return _checked((None, "model", None), shape, mesh)
        return _checked((None, None, "model"), shape, mesh)
    if name == "D":
        return _checked((None, "model"), shape, mesh)
    if in_moe and name in ("w_gate", "w_up", "w_down"):  # [L,E,d,ff]/[L,E,ff,d]
        # Expert-parallel over "data" + megatron TP over "model" inside each
        # expert.  Tokens reach their expert via an all-to-all on "data" (the
        # GShard schedule); d_model stays unsharded so activations keep their
        # batch sharding.
        E = shape[1]
        e_ax = "data" if ("data" in mesh.axis_names and _fits(E, mesh, "data")) \
            else ("model" if _fits(E, mesh, "model") else None)
        tp_ax = "model" if e_ax != "model" else None
        if name == "w_down":                          # [L,E,ff,d]
            return _checked((None, e_ax, tp_ax, None), shape, mesh)
        return _checked((None, e_ax, None, tp_ax), shape, mesh)
    if "/channel/" in path and name == "wv":          # rwkv channel [L,ff,d]
        return tp(1, (None, "model", None))
    if name in _ROW:
        return tp(1, (None, "model", None))
    if name in _COL:
        return tp(0, (None, None, "model"))
    if name in _REP or shape == () or len(shape) <= 2:
        return P(*([None] * len(shape)))
    return P(*([None] * len(shape)))


def param_pspecs(cfg: ModelConfig, params: Any, mesh: Mesh) -> Any:
    fsdp = cfg.arch_id in FSDP_ARCHS or cfg.parallel_strategy == "fsdp"
    return jax.tree_util.tree_map_with_path(
        lambda path, x: param_spec(_path_str(path), x.shape, mesh, fsdp,
                                   tied=cfg.tie_embeddings), params)


def zero1_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Add "data" sharding to the first replicated, divisible dim (ZeRO-1)."""
    if "data" not in mesh.axis_names:
        return spec
    used = set()
    for s in spec:
        if s is None:
            continue
        for a in (s if isinstance(s, tuple) else (s,)):
            used.add(a)
    if "data" in used:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, s) in enumerate(zip(shape, parts)):
        if s is None and _fits(dim, mesh, "data"):
            parts[i] = "data"
            return P(*parts)
    return spec


def opt_pspecs(cfg: ModelConfig, params: Any, mesh: Mesh) -> Any:
    base = param_pspecs(cfg, params, mesh)
    return jax.tree.map(
        lambda x, s: zero1_spec(s, x.shape, mesh), params, base)


def batch_pspecs(batch: Dict[str, Any], mesh: Mesh) -> Dict[str, P]:
    ba = batch_axes(mesh)
    out = {}
    for k, v in batch.items():
        dims = getattr(v, "ndim", None) or len(v.shape)
        b = v.shape[0]
        ax = ba if (ba and b % int(np.prod([mesh.shape[a] for a in ba])) == 0) else None
        out[k] = P(*((ax,) + (None,) * (dims - 1)))
    return out


def cache_pspecs(cfg: ModelConfig, cache: Any, mesh: Mesh, seq_len: int) -> Any:
    """KV cache: [L, B, S, ...] → B on ("pod","data"), S on "model";
    recurrent states: channel/head dims on "model"."""
    ba = batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1

    def spec(kp, x):
        name = _path_str(kp).split("/")[-1]
        shape = x.shape
        b_ax = ba if (len(shape) > 1 and shape[1] % max(nb, 1) == 0 and ba) else None
        if name in ("k", "v"):            # [L,B,S,KV,hd]
            s_ax = "model" if _fits(shape[2], mesh, "model") else None
            return P(None, b_ax, s_ax, None, None)
        if name in ("c_kv", "k_rope"):    # [L,B,S,r]
            s_ax = "model" if _fits(shape[2], mesh, "model") else None
            return P(None, b_ax, s_ax, None)
        if name == "wkv":                 # [L,B,H,n,n]
            h_ax = "model" if _fits(shape[2], mesh, "model") else None
            return P(None, b_ax, h_ax, None, None)
        if name == "h":                   # [L,B,di,N]
            d_ax = "model" if _fits(shape[2], mesh, "model") else None
            return P(None, b_ax, d_ax, None)
        if name == "conv":                # [L,B,K,di]
            d_ax = "model" if _fits(shape[3], mesh, "model") else None
            return P(None, b_ax, None, d_ax)
        if name in ("tm_x", "cm_x"):      # [L,B,d]
            d_ax = "model" if _fits(shape[2], mesh, "model") else None
            return P(None, b_ax, d_ax)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache)


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
