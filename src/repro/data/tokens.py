"""Deterministic synthetic LM data pipeline.

Every (shard, step) pair maps to an independent PRNG stream, so the pipeline
is (a) deterministic under restart — resuming at step k regenerates exactly
the batches a failed run would have seen — and (b) heterogeneity-aware:
per-device batch shares come from the MB-scheduler plan
(``repro.data.sharding``), not a fixed equal split.

The synthetic distribution is a Zipf mixture with Markov bigram structure so
the loss actually decreases during the example training runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_modes: int = 8            # bigram mixture modes


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # low-rank bigram structure: next ~ Zipf permuted by mode
        self.perms = np.stack([rng.permutation(V) for _ in range(cfg.n_modes)])
        zipf_p = 1.0 / (np.arange(1, V + 1) ** 1.1)
        self.zipf_p = zipf_p / zipf_p.sum()

    def batch(self, step: int, batch_size: Optional[int] = None,
              offset: int = 0) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, offset) — offset selects the slice
        of the global batch (device/microbatch addressing)."""
        cfg = self.cfg
        bs = batch_size or cfg.global_batch
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, offset]))
        mode = rng.integers(cfg.n_modes, size=(bs, 1))
        base = rng.choice(cfg.vocab_size, p=self.zipf_p,
                          size=(bs, cfg.seq_len))
        toks = self.perms[mode[:, 0]][np.arange(bs)[:, None],
                                      np.minimum(base, cfg.vocab_size - 1)]
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
