"""Heterogeneity-aware shard-size planning — the MB Scheduler applied to the
LM data pipeline (DESIGN.md §2: "multi-threaded task → split ∝ core power").

Given a device profile and a global batch, the planner assigns each
data-parallel rank a microbatch *count* proportional to its measured
throughput (counts, not sizes: every microbatch keeps the same static shape,
so one compiled program serves all ranks — re-planning is a new integer
vector, not a re-compile).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.scheduler import MBScheduler, TaskSpec


@dataclass
class BatchPlan:
    microbatch: int                 # tokens dimension kept static
    counts: np.ndarray              # [n_ranks] microbatches per rank per step
    global_batch: int

    @property
    def step_batches(self) -> int:
        return int(self.counts.sum())


def plan_batches(profile: HeterogeneityProfile, global_batch: int,
                 microbatch: int) -> BatchPlan:
    """Split `global_batch` into microbatches of size `microbatch` and
    assign counts ∝ speed (largest remainder, exact sum)."""
    if global_batch % microbatch != 0:
        raise ValueError(f"global_batch {global_batch} % microbatch {microbatch} != 0")
    n_micro = global_batch // microbatch
    sched = MBScheduler(profile, policy="proportional")
    asg = sched.assign_parallel(
        TaskSpec("batch-plan", float(n_micro), parallel=True, n_tiles=n_micro))
    counts = np.array([len(ts) for ts in asg.tiles_of])
    assert counts.sum() == n_micro
    return BatchPlan(microbatch=microbatch, counts=counts,
                     global_batch=global_batch)


def replan(profile: HeterogeneityProfile, plan: BatchPlan) -> BatchPlan:
    """Dynamic re-plan after EWMA throughput updates (core switching)."""
    return plan_batches(profile, plan.global_batch, plan.microbatch)


def plan_shard_rows(profile: HeterogeneityProfile, n_rows: int,
                    row_block: int = 8,
                    alive: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-rank *real* row counts for a sharded bitmap: blocks of `row_block`
    rows split ∝ speed over the alive ranks (dead ranks get 0), Σ equal to
    `n_rows` rounded up to a block multiple.

    This is the mining plane's version of `plan_batches`: every shard keeps
    one static padded shape, so heterogeneity (and failure re-plans) change
    only this integer vector, never the compiled program.
    """
    if n_rows <= 0:
        raise ValueError(f"n_rows must be positive, got {n_rows}")
    alive = (np.ones(profile.n, dtype=bool) if alive is None
             else np.asarray(alive, dtype=bool))
    if alive.shape != (profile.n,):
        raise ValueError(f"alive mask shape {alive.shape} != ({profile.n},)")
    if not alive.any():
        raise RuntimeError("all ranks dead — nothing can hold the bitmap")
    n_blocks = -(-n_rows // row_block)             # ceil
    sub = HeterogeneityProfile(profile.speeds[alive])
    plan = plan_batches(sub, n_blocks * row_block, row_block)
    rows = np.zeros(profile.n, dtype=np.int64)
    rows[np.nonzero(alive)[0]] = plan.counts * row_block
    return rows
