"""Heterogeneity-aware shard-size planning — the MB Scheduler applied to the
LM data pipeline (DESIGN.md §2: "multi-threaded task → split ∝ core power").

Given a device profile and a global batch, the planner assigns each
data-parallel rank a microbatch *count* proportional to its measured
throughput (counts, not sizes: every microbatch keeps the same static shape,
so one compiled program serves all ranks — re-planning is a new integer
vector, not a re-compile).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.scheduler import MBScheduler, TaskSpec


@dataclass
class BatchPlan:
    microbatch: int                 # tokens dimension kept static
    counts: np.ndarray              # [n_ranks] microbatches per rank per step
    global_batch: int

    @property
    def step_batches(self) -> int:
        return int(self.counts.sum())


def plan_batches(profile: HeterogeneityProfile, global_batch: int,
                 microbatch: int) -> BatchPlan:
    """Split `global_batch` into microbatches of size `microbatch` and
    assign counts ∝ speed (largest remainder, exact sum)."""
    if global_batch % microbatch != 0:
        raise ValueError(f"global_batch {global_batch} % microbatch {microbatch} != 0")
    n_micro = global_batch // microbatch
    sched = MBScheduler(profile, policy="proportional")
    asg = sched.assign_parallel(
        TaskSpec("batch-plan", float(n_micro), parallel=True, n_tiles=n_micro))
    counts = np.array([len(ts) for ts in asg.tiles_of])
    assert counts.sum() == n_micro
    return BatchPlan(microbatch=microbatch, counts=counts,
                     global_batch=global_batch)


def replan(profile: HeterogeneityProfile, plan: BatchPlan) -> BatchPlan:
    """Dynamic re-plan after EWMA throughput updates (core switching)."""
    return plan_batches(profile, plan.global_batch, plan.microbatch)
