"""Sparse CSR transaction slab — the format that lets production item
universes skip the dense bitmap.

The dense layout is O(n_tx × n_items) regardless of how empty it is;
SNIPPET 2's retail dataset (1559 items, 0.42% max item frequency) spends
99.5%+ of those bytes on zeros.  :class:`SparseSlab` stores the same
transactions as CSR (row pointers + sorted item ids per transaction) and
converts in three directions:

* ``to_dense()``       — the Apriori tiling path (explicit, never implicit);
* ``tid_columns()``    — straight to the Eclat vertical layout: one packed
  uint32 tid-list word row per item, built by scattering bits from the
  CSR indices **without** materializing the dense [n_tx, n_items] matrix;
* ``from_dense()``     — round-trip back for parity tests.

``density_stats`` measures the features the algorithm auto-selector
feeds the cost model (density, per-item frequencies) from either format.

Bit convention (shared with ``kernels.support_count.fused.pack_words``):
bit b of word w holds transaction ``w * 32 + b`` (LSB-first).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Union

import numpy as np

WORD_BITS = 32


def _pad_up(n: int, multiple: int) -> int:
    return n + (-n) % multiple


@dataclass(frozen=True)
class SparseSlab:
    """CSR transactions: row t holds sorted unique item ids
    ``indices[indptr[t]:indptr[t+1]]``."""

    indptr: np.ndarray            # int64 [n_tx + 1], monotone, [0] == 0
    indices: np.ndarray           # int32 [nnz], sorted + deduped per row
    n_items: int

    @property
    def n_tx(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def density(self) -> float:
        cells = self.n_tx * self.n_items
        return self.nnz / cells if cells else 0.0

    # ------------------------------------------------------------------
    @classmethod
    def from_baskets(cls, baskets: Sequence[Sequence[int]],
                     n_items: int = 0) -> "SparseSlab":
        """Variable-length id lists → CSR (set semantics: duplicates in one
        basket collapse, ids sorted per row — same as ``pack_transactions``)."""
        rows: List[np.ndarray] = []
        max_id = -1
        for tx in baskets:
            ids = np.unique(np.asarray(list(tx), dtype=np.int64)) \
                if len(tx) else np.zeros(0, np.int64)
            if len(ids):
                if ids[0] < 0:
                    raise ValueError("item ids must be non-negative")
                max_id = max(max_id, int(ids[-1]))
            rows.append(ids)
        if n_items <= 0:
            n_items = max_id + 1 if max_id >= 0 else 1
        elif max_id >= n_items:
            raise ValueError(f"item id {max_id} out of range [0, {n_items})")
        indptr = np.zeros(len(rows) + 1, dtype=np.int64)
        np.cumsum([len(r) for r in rows], out=indptr[1:])
        indices = (np.concatenate(rows).astype(np.int32) if rows
                   else np.zeros(0, np.int32))
        return cls(indptr=indptr, indices=indices, n_items=int(n_items))

    @classmethod
    def from_dense(cls, T: np.ndarray) -> "SparseSlab":
        """0/1 bitmap [n_tx, n_items] → CSR (exact round-trip partner of
        ``to_dense``)."""
        T = np.asarray(T)
        if T.ndim != 2:
            raise ValueError(f"bitmap must be 2-D, got {T.shape}")
        if T.size and not ((T == 0) | (T == 1)).all():
            raise ValueError("bitmap must contain only 0/1")
        rows, cols = np.nonzero(T)
        indptr = np.zeros(T.shape[0] + 1, dtype=np.int64)
        np.cumsum(np.bincount(rows, minlength=T.shape[0]), out=indptr[1:])
        # np.nonzero is row-major, so cols are already sorted per row
        return cls(indptr=indptr, indices=cols.astype(np.int32),
                   n_items=int(T.shape[1]))

    # ------------------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        """CSR → 0/1 uint8 bitmap [n_tx, n_items] (the Apriori layout)."""
        T = np.zeros((self.n_tx, self.n_items), dtype=np.uint8)
        rows = np.repeat(np.arange(self.n_tx), np.diff(self.indptr))
        T[rows, self.indices] = 1
        return T

    def item_counts(self) -> np.ndarray:
        """Per-item transaction frequency [n_items] int64 — the k=1 supports
        and the auto-selector's sparsity feature, no densification."""
        return np.bincount(self.indices, minlength=self.n_items
                           ).astype(np.int64)

    def tid_columns(self, row_pad: int = 128,
                    word_pad: int = 128) -> np.ndarray:
        """Packed uint32 tid-list columns [n_items→row_pad·, W→word_pad·]:
        bit b of word w in row i set iff transaction ``32w + b`` contains
        item i.  Built by scattering bits straight from the CSR triplets —
        the dense [n_tx, n_items] matrix is never formed, which is the
        whole point of the sparse path."""
        n_rows = _pad_up(max(self.n_items, 1), row_pad)
        n_words = _pad_up(max((self.n_tx + WORD_BITS - 1) // WORD_BITS, 1),
                          word_pad)
        cols = np.zeros((n_rows, n_words), dtype=np.uint32)
        if self.nnz:
            tids = np.repeat(np.arange(self.n_tx, dtype=np.int64),
                             np.diff(self.indptr))
            np.bitwise_or.at(
                cols, (self.indices.astype(np.int64), tids >> 5),
                np.uint32(1) << (tids & 31).astype(np.uint32))
        return cols


@dataclass(frozen=True)
class DensityStats:
    """The measured features the algorithm auto-selector feeds the cost
    model — computed from either slab format without densifying."""

    n_tx: int
    n_items: int
    nnz: int
    density: float                   # nnz / (n_tx * n_items)
    item_counts: np.ndarray          # [n_items] int64 tx frequency per item
    max_item_frequency: float        # max item_counts / n_tx

    def summary(self) -> str:
        return (f"{self.n_tx} tx x {self.n_items} items, nnz={self.nnz} "
                f"(density {self.density:.4f}, max item freq "
                f"{self.max_item_frequency:.4f})")


BasketsLike = Union[np.ndarray, SparseSlab, Sequence[Sequence[int]]]


def density_stats(baskets: BasketsLike) -> DensityStats:
    """Measure density features from a dense bitmap, a :class:`SparseSlab`,
    or raw id lists — the sparse path never builds the dense matrix."""
    if isinstance(baskets, SparseSlab):
        slab = baskets
    elif isinstance(baskets, np.ndarray):
        counts = np.asarray(baskets, dtype=np.int64).sum(axis=0)
        n_tx, n_items = baskets.shape
        nnz = int(counts.sum())
        return DensityStats(
            n_tx=n_tx, n_items=n_items, nnz=nnz,
            density=nnz / (n_tx * n_items) if baskets.size else 0.0,
            item_counts=counts,
            max_item_frequency=(float(counts.max()) / n_tx
                                if n_tx and n_items else 0.0))
    else:
        slab = SparseSlab.from_baskets(baskets)
    counts = slab.item_counts()
    return DensityStats(
        n_tx=slab.n_tx, n_items=slab.n_items, nnz=slab.nnz,
        density=slab.density, item_counts=counts,
        max_item_frequency=(float(counts.max()) / slab.n_tx
                            if slab.n_tx and slab.n_items else 0.0))


def pack_tid_columns(T: np.ndarray, row_pad: int = 128,
                     word_pad: int = 128) -> np.ndarray:
    """Dense 0/1 bitmap [n_tx, n_items] → packed tid columns (the dense-
    input twin of ``SparseSlab.tid_columns``, same bit convention)."""
    return SparseSlab.from_dense(np.asarray(T)).tid_columns(
        row_pad=row_pad, word_pad=word_pad)
