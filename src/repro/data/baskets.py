"""Synthetic transactional database (IBM Quest–style) for Market Basket
Analysis, plus bitmap packing.

Generates transactions from a pool of "purchase patterns" (correlated
itemsets) mixed with Zipf-distributed noise, which yields the non-trivial
association rules the paper mines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class BasketConfig:
    n_tx: int = 4096
    n_items: int = 128          # padded to a multiple of 128 for the kernel
    n_patterns: int = 12
    pattern_len: int = 4
    pattern_prob: float = 0.35  # probability a tx includes a pattern
    noise_items: int = 3
    zipf_a: float = 1.5
    seed: int = 0


def generate_baskets(cfg: BasketConfig) -> np.ndarray:
    """Returns T ∈ uint8[n_tx, n_items] with 0/1 entries."""
    rng = np.random.default_rng(cfg.seed)
    patterns = [rng.choice(cfg.n_items, size=cfg.pattern_len, replace=False)
                for _ in range(cfg.n_patterns)]
    T = np.zeros((cfg.n_tx, cfg.n_items), dtype=np.uint8)
    for t in range(cfg.n_tx):
        if rng.random() < cfg.pattern_prob:
            pat = patterns[rng.integers(cfg.n_patterns)]
            keep = rng.random(len(pat)) < 0.9          # occasionally drop one
            T[t, pat[keep]] = 1
        noise = rng.zipf(cfg.zipf_a, size=cfg.noise_items) % cfg.n_items
        T[t, noise] = 1
    return T


def stationary_baskets(n_tx: int, n_items: int, n_patterns: int = 6,
                       pattern_len: int = 3, seed: int = 0) -> np.ndarray:
    """A stationary, wide-margin stream for the incremental-mining plane.

    Every transaction is one of ``n_patterns`` *disjoint* purchase patterns
    plus a single uniform noise item, so itemset supports concentrate far
    from any reasonable min_support threshold (pattern itemsets ≈
    ``window / n_patterns``, noise ≈ ``window / n_items``).  Under such a
    stream the frequent-set lattice is stable across micro-batches and the
    streaming miner's delta path never needs a full re-validation — the
    steady state the B10 benchmark measures.  ``generate_baskets`` with its
    Zipf noise is the opposite regime: many itemsets hover at the
    threshold and cross it every batch.
    """
    if n_patterns * pattern_len > n_items:
        raise ValueError(f"{n_patterns} disjoint patterns of length "
                         f"{pattern_len} need more than {n_items} items")
    rng = np.random.default_rng(seed)
    ids = rng.permutation(n_items)[:n_patterns * pattern_len]
    patterns = ids.reshape(n_patterns, pattern_len)
    T = np.zeros((n_tx, n_items), dtype=np.uint8)
    for t in range(n_tx):
        T[t, patterns[rng.integers(n_patterns)]] = 1
        T[t, rng.integers(n_items)] = 1
    return T


def sparse_baskets(n_tx: int, n_items: int, basket_len: int = 8,
                   max_item_freq: float = 0.01, n_patterns: int = 20,
                   pattern_len: int = 3, seed: int = 0
                   ) -> List[List[int]]:
    """A wide-universe, low-frequency corpus (SNIPPET 2's retail regime:
    1559 items, 0.42% max item frequency) as raw id lists — the input the
    sparse slab path consumes *without* ever building the dense bitmap.

    Each transaction draws one of ``n_patterns`` correlated patterns with
    probability ``max_item_freq * n_patterns`` (a uniform pattern choice
    then caps every pattern item's frequency near ``max_item_freq``) plus
    ``basket_len`` uniform noise items from the full universe, whose
    individual frequencies sit near ``basket_len / n_items`` — far below
    the cap for production-sized universes.
    """
    if n_patterns * pattern_len > n_items:
        raise ValueError(f"{n_patterns} patterns of length {pattern_len} "
                         f"do not fit in a {n_items}-item universe")
    rng = np.random.default_rng(seed)
    ids = rng.permutation(n_items)[:n_patterns * pattern_len]
    patterns = ids.reshape(n_patterns, pattern_len)
    p_pattern = min(max_item_freq * n_patterns, 1.0)
    baskets: List[List[int]] = []
    for _ in range(n_tx):
        tx = set(rng.choice(n_items, size=basket_len, replace=False).tolist())
        if rng.random() < p_pattern:
            tx.update(patterns[rng.integers(n_patterns)].tolist())
        baskets.append(sorted(tx))
    return baskets


def pack_transactions(transactions: Sequence[Sequence[int]],
                      n_items: Optional[int] = None) -> np.ndarray:
    """Pack variable-length transactions (sequences of item ids) into the
    dense 0/1 bitmap the data plane consumes.  Duplicate items within one
    transaction collapse to a single bit (set semantics)."""
    if n_items is None:
        n_items = 1 + max((max(tx) for tx in transactions if len(tx)),
                          default=-1)
    T = np.zeros((len(transactions), max(n_items, 1)), dtype=np.uint8)
    for t, tx in enumerate(transactions):
        if not len(tx):
            continue
        idx = np.asarray(list(tx))
        if idx.min() < 0 or idx.max() >= n_items:
            raise ValueError(
                f"item ids must be in [0, {n_items}) — negative or oversized "
                "ids would land in the wrong bitmap column")
        T[t, idx] = 1
    return T


def pad_items(T: np.ndarray, multiple: int = 128) -> np.ndarray:
    """Pad the item axis to a lane-aligned multiple (kernel requirement)."""
    n_tx, n_items = T.shape
    pad = (-n_items) % multiple
    if pad == 0:
        return T
    return np.pad(T, ((0, 0), (0, pad)))


def pad_rows(T: np.ndarray, multiple: int = 8) -> np.ndarray:
    n_tx, _ = T.shape
    pad = (-n_tx) % multiple
    if pad == 0:
        return T
    return np.pad(T, ((0, pad), (0, 0)))
