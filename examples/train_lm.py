"""End-to-end LM training driver: a ~100M-parameter Gemma-3-style model for
a few hundred steps on the synthetic pipeline, with checkpointing and a
mid-run straggler injection that triggers an MB-scheduler re-plan.

Full run (~100M params, 300 steps — takes a while on 1 CPU core):
  PYTHONPATH=src python examples/train_lm.py
Quick check (~5M params, 60 steps):
  PYTHONPATH=src python examples/train_lm.py --quick
"""
import argparse

from repro.configs.base import get_config, register, ModelConfig
from repro.core.hetero import HeterogeneityProfile
from repro.distributed.fault import FaultEvent, FaultPlan
from repro.launch.train import train


def register_demo_configs():
    def demo_100m() -> ModelConfig:
        return get_config("gemma3-1b").replace(
            n_layers=8, d_model=768, n_heads=8, n_kv_heads=2, head_dim=96,
            d_ff=2048, vocab_size=32768, local_window=256, global_every=4)

    def demo_5m() -> ModelConfig:
        return demo_100m().replace(
            n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
            d_ff=512, vocab_size=4096)

    register("demo-100m", demo_100m, demo_5m)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    register_demo_configs()

    steps = args.steps or (60 if args.quick else 300)
    fault = FaultPlan([FaultEvent(step=steps // 2, kind="straggler",
                                  device=0, severity=3.0)])
    hist = train("demo-100m",
                 smoke=args.quick,
                 steps=steps,
                 batch=8 if args.quick else 16,
                 seq=128 if args.quick else 512,
                 lr=3e-3,
                 grad_accum=1 if args.quick else 2,
                 ckpt_dir=args.ckpt_dir, ckpt_every=max(steps // 4, 10),
                 restore=True,
                 profile=HeterogeneityProfile.homogeneous(4),
                 fault_plan=fault,
                 log_every=max(steps // 20, 1))
    print(f"\nfinal loss {hist['loss'][-1]:.4f} "
          f"(start {hist['loss'][0]:.4f}); re-plans: {hist['replans']}")


if __name__ == "__main__":
    main()
