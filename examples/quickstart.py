"""Quickstart: the paper end-to-end through MarketBasketPipeline.

One object runs the whole composition: basket ingestion → bitmap packing →
MapReduce Apriori rounds under the MB Scheduler on the paper's
heterogeneous 80/120/200/400 four-core system (serial candidate generation
gated to one core, support counting tiled across all four) → association
rules → a structured PipelineReport with timing / energy / core-switch
accounting.  The LPT policy is then compared against a naive Hadoop-style
equal split.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.pipeline import MarketBasketPipeline, PipelineConfig

# 1. transactional data (IBM-Quest-style synthetic store data)
T = generate_baskets(BasketConfig(n_tx=4096, n_items=96, seed=42))

# 2. the full pipeline on the paper's system, per split strategy
profile = HeterogeneityProfile.paper()            # 80 / 120 / 200 / 400
results = {}
for split in ("equal", "proportional", "lpt"):
    pipe = MarketBasketPipeline(
        profile,
        PipelineConfig(min_support=80, min_confidence=0.65,
                       n_tiles=32, split=split))
    results[split] = pipe.run(T)

# 3. the structured report for the MB Scheduler (LPT) run
best = results["lpt"]
print(best.report.summary())

# map phases only: the serial phases are identical under every policy, so
# this is the ratio the paper's analytic bound speaks about
speedup = (results["equal"].report.map_time_s
           / results["lpt"].report.map_time_s)
saved = (results["equal"].report.total_energy_j
         - results["lpt"].report.total_energy_j)
print(f"\nMB Scheduler (lpt) vs naive equal split: {speedup:.2f}x faster, "
      f"saving {saved:.1f} J "
      f"(paper's analytic bound for this core mix: 2.50x)")

# 4. the mined rules (paper step 3)
print(f"\ntop rules (of {len(best.rules)}):")
for r in best.rules[:8]:
    print("  ", r)

# 5. online serving: compile the rules into a device-resident index and
#    answer "given this basket, which items next?" in scheduled batches
from repro.serving import Query, RecommendationEngine, RuleIndex

engine = RecommendationEngine(RuleIndex.build(best.rules, T.shape[1]), profile)
recs, serving = engine.serve([Query.of(row) for row in T[:64]])
print("\n" + serving.summary())
