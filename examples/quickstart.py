"""Quickstart: the paper's pipeline in 30 lines.

Generates a transactional database, mines association rules with the
3-step MapReduce Apriori under the MB Scheduler on the paper's
heterogeneous 80/120/200/400 four-core system, and compares the makespan
against a naive Hadoop-style equal split.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori
from repro.core.mapreduce import SimulatedCluster
from repro.core.power import PowerModel
from repro.core.rules import generate_rules
from repro.core.scheduler import MBScheduler
from repro.data.baskets import BasketConfig, generate_baskets, pad_items

# 1. transactional data (IBM-Quest-style synthetic store data)
T = pad_items(generate_baskets(BasketConfig(n_tx=4096, n_items=96, seed=42)))

# 2. the paper's system: 4 heterogeneous cores, MB Scheduler, power model
profile = HeterogeneityProfile.paper()            # 80 / 120 / 200 / 400
results = {}
for policy in ("equal", "proportional", "lpt"):
    cluster = SimulatedCluster(profile, MBScheduler(profile, policy),
                               power=PowerModel.cpu(profile))
    res = apriori(T, min_support=80, cluster=cluster, n_tiles=32)
    makespan = sum(rep.makespan for _, rep in res.reports)
    energy = sum(rep.energy_j or 0 for _, rep in res.reports)
    results[policy] = (makespan, energy, res)
    print(f"{policy:13s} makespan={makespan:.4f}s  energy={energy:.1f}J  "
          f"itemsets={len(res.supports)}")

speedup = results["equal"][0] / results["lpt"][0]
print(f"\nMB Scheduler speedup over equal split: {speedup:.2f}x "
      f"(paper's analytic bound for this core mix: 2.50x)")

# 3. association rules (paper step 3)
rules = generate_rules(results["lpt"][2], min_confidence=0.65)
print(f"\ntop rules (of {len(rules)}):")
for r in rules[:8]:
    print("  ", r)
