"""Fault-tolerance walkthrough: train, kill, restart from checkpoint,
shrink the cluster elastically, and keep training — the pod-scale version
of the paper's "switch off the unused cores".

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import shutil

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.distributed.fault import FaultEvent, FaultPlan
from repro.launch.train import train

CKPT = "/tmp/repro_elastic_demo"
shutil.rmtree(CKPT, ignore_errors=True)

print("=== phase 1: 8-rank cluster, checkpoint every 10 steps ===")
h1 = train("hymba-1.5b", steps=20, smoke=True, batch=8, seq=64, lr=2e-3,
           ckpt_dir=CKPT, ckpt_every=10,
           profile=HeterogeneityProfile.homogeneous(8), log_every=5)

print("\n=== phase 2: 'crash'; restart from latest checkpoint, lose rank 3, ")
print("===          then a straggler appears at step 30 ===")
fault = FaultPlan([
    FaultEvent(step=25, kind="device_loss", device=3),
    FaultEvent(step=30, kind="straggler", device=0, severity=4.0),
])
h2 = train("hymba-1.5b", steps=40, smoke=True, batch=8, seq=64, lr=2e-3,
           ckpt_dir=CKPT, ckpt_every=10, restore=True,
           profile=HeterogeneityProfile.homogeneous(8),
           fault_plan=fault, log_every=5)

print(f"\nloss continued {h1['loss'][-1]:.4f} -> {h2['loss'][-1]:.4f}; "
      f"elastic re-plans: {h2['replans']}")
assert np.isfinite(h2["loss"]).all()
print("fault-tolerant restart: OK")
