"""Batched serving demo: prefill + temperature decode on three different
architecture families (dense GQA, RWKV-6 recurrent state, hybrid
attention+SSM) through the same serving API.

  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import serve_demo

for arch in ("granite-3-8b", "rwkv6-7b", "hymba-1.5b"):
    out = serve_demo(arch, batch=4, prompt_len=24, new_tokens=24,
                     temperature=0.8, smoke=True)
    print(f"  {arch}: first sampled rows {out['tokens'][:2, :8].tolist()}\n")
