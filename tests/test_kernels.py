"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rwkv6_wkv.ops import wkv6
from repro.kernels.rwkv6_wkv.ref import wkv6_ref
from repro.kernels.support_count.ops import support_count
from repro.kernels.support_count.ref import support_count_ref


# ---------------------------------------------------------------------------
# support_count
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_tx,n_items,n_cand", [
    (64, 40, 10), (512, 128, 256), (1000, 200, 37), (8, 128, 1),
    (256, 256, 300), (17, 33, 5),
])
def test_support_count_shapes(n_tx, n_items, n_cand):
    rng = np.random.default_rng(n_tx + n_items)
    T = (rng.random((n_tx, n_items)) < 0.3).astype(np.uint8)
    C = np.zeros((n_cand, n_items), np.uint8)
    for m in range(n_cand):
        C[m, rng.choice(n_items, size=rng.integers(1, 5), replace=False)] = 1
    got = np.asarray(support_count(jnp.asarray(T), jnp.asarray(C)))
    want = np.asarray(support_count_ref(jnp.asarray(T), jnp.asarray(C)))
    np.testing.assert_array_equal(got, want)


def test_support_count_empty_and_full_rows():
    T = np.zeros((16, 128), np.uint8)
    T[0] = 1
    C = np.eye(128, dtype=np.uint8)[:4]
    got = np.asarray(support_count(jnp.asarray(T), jnp.asarray(C)))
    np.testing.assert_array_equal(got, np.ones(4, np.int32))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,win,dtype", [
    (1, 128, 4, 2, 64, 0, jnp.float32),
    (2, 256, 4, 1, 32, 0, jnp.float32),
    (1, 128, 2, 2, 64, 48, jnp.float32),
    (1, 256, 8, 8, 128, 0, jnp.bfloat16),
    (2, 64, 4, 4, 64, 16, jnp.bfloat16),
])
def test_flash_attention_vs_ref(B, S, H, KV, hd, win, dtype):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    got = flash_attention(q, k, v, window=win, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_block_shape_independence():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 256, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 32)), jnp.float32)
    a = flash_attention(q, k, v, bq=64, bk=64)
    b = flash_attention(q, k, v, bq=128, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,n,chunk", [
    (1, 64, 2, 16, 16), (2, 128, 4, 64, 32), (1, 96, 1, 32, 32),
    (1, 64, 2, 64, 64),
])
def test_wkv6_vs_sequential_ref(B, T, H, n, chunk):
    rng = np.random.default_rng(T + n)
    r, k, v = (jnp.asarray(rng.standard_normal((B, T, H, n)) * 0.5, jnp.float32)
               for _ in range(3))
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((B, T, H, n)) * 0.5 - 1.0)),
                    jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, n)) * 0.5, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, n, n)) * 0.1, jnp.float32)
    y, sf = wkv6(r, k, v, w, u, s0, chunk=chunk)
    yr, sfr = wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr), atol=5e-4)


def test_wkv6_chunk_boundary_equivalence():
    """Same input, different chunk sizes -> identical recurrence."""
    rng = np.random.default_rng(5)
    B, T, H, n = 1, 128, 2, 32
    args = [jnp.asarray(rng.standard_normal((B, T, H, n)) * 0.4, jnp.float32)
            for _ in range(3)]
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((B, T, H, n)) - 1)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, n)) * 0.3, jnp.float32)
    y16, s16 = wkv6(*args, w, u, chunk=16)
    y64, s64 = wkv6(*args, w, u, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s64), atol=5e-4)


def test_wkv6_extreme_decay_stable():
    """Near-zero and near-one decays must not produce inf/nan."""
    B, T, H, n = 1, 64, 1, 16
    rng = np.random.default_rng(9)
    r, k, v = (jnp.asarray(rng.standard_normal((B, T, H, n)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(np.where(rng.random((B, T, H, n)) < 0.5, 0.01, 0.9999),
                    jnp.float32)
    u = jnp.zeros((H, n), jnp.float32)
    y, sf = wkv6(r, k, v, w, u, chunk=32)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(sf)).all()
    yr, _ = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)


# ---------------------------------------------------------------------------
# selective_scan (Mamba/Hymba SSM)
# ---------------------------------------------------------------------------

from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref


@pytest.mark.parametrize("B,T,D,N,c,dk", [
    (1, 64, 64, 16, 16, 64), (2, 128, 128, 16, 32, 64), (1, 48, 32, 8, 16, 32),
    (1, 64, 64, 4, 64, 16),
])
def test_selective_scan_vs_ref(B, T, D, N, c, dk):
    rng = np.random.default_rng(T + D)
    a = jnp.asarray(np.exp(-np.exp(rng.standard_normal((B, T, D, N)) * 0.5 - 1)),
                    jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, T, D, N)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((B, T, N)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, D, N)) * 0.2, jnp.float32)
    y, hf = selective_scan(a, b, C, h0, chunk=c, d_blk=dk)
    yr, hfr = selective_scan_ref(a, b, C, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr), atol=1e-4)


def test_selective_scan_matches_model_ssm_math():
    """Kernel == the model's _selective_scan on the same a/b decomposition."""
    from repro.models.ssm import _selective_scan
    rng = np.random.default_rng(11)
    B, S, di, N = 1, 64, 32, 8
    u = jnp.asarray(rng.standard_normal((B, S, di)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, di))) * 0.2 + 0.01,
                     jnp.float32)
    A = -jnp.asarray(np.abs(rng.standard_normal((di, N))) + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Dv = jnp.asarray(rng.standard_normal(di), jnp.float32)
    y_model, h_model = _selective_scan(u, dt, A, Bm, Cm, Dv)
    a = jnp.exp(dt[..., None] * A[None, None])
    b = dt[..., None] * Bm[:, :, None, :] * u[..., None]
    y_k, h_k = selective_scan(a, b, Cm, chunk=16, d_blk=32)
    y_k = y_k + Dv[None, None] * u
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_model), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_model), atol=2e-4)


def test_selective_scan_extreme_decay_stable():
    B, T, D, N = 1, 32, 16, 4
    rng = np.random.default_rng(3)
    a = jnp.asarray(np.where(rng.random((B, T, D, N)) < 0.5, 1e-4, 0.99999),
                    jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, T, D, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((B, T, N)), jnp.float32)
    y, hf = selective_scan(a, b, C, chunk=16, d_blk=16)
    assert np.isfinite(np.asarray(y)).all()
    yr, _ = selective_scan_ref(a, b, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
