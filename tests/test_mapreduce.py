"""MapReduce engine: result correctness under any schedule/failures, and
combiner associativity (hypothesis)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.hetero import HeterogeneityProfile
from repro.core.mapreduce import (FailureEvent, MapReduceJob,
                                  SimulatedCluster)
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler


def word_count_job(n_items):
    return MapReduceJob(
        name="wc",
        map_fn=lambda tile: np.bincount(tile, minlength=n_items),
        combine_fn=lambda a, b: a + b,
        zero_fn=lambda: np.zeros(n_items, np.int64),
        cost_fn=lambda tile: float(len(tile)),
    )


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(1, 40), st.integers(0, 2**31 - 1),
       st.sampled_from(["lpt", "proportional", "equal"]))
def test_result_independent_of_schedule(n_dev, n_tiles, seed, policy):
    rng = np.random.default_rng(seed)
    tiles = [rng.integers(0, 16, rng.integers(1, 50)) for _ in range(n_tiles)]
    want = np.bincount(np.concatenate(tiles), minlength=16)
    profile = HeterogeneityProfile(rng.uniform(0.5, 8.0, n_dev))
    cluster = SimulatedCluster(profile, MBScheduler(profile, policy))
    got, rep = cluster.run(word_count_job(16), tiles)
    assert (got == want).all()
    assert rep.makespan > 0


def test_failure_recovery_preserves_result():
    rng = np.random.default_rng(3)
    tiles = [rng.integers(0, 8, 20) for _ in range(24)]
    want = np.bincount(np.concatenate(tiles), minlength=8)
    profile = HeterogeneityProfile.paper()
    cluster = SimulatedCluster(profile)
    got, rep = cluster.run(word_count_job(8), tiles,
                           failures=[FailureEvent(device=3, at_time=0.01)])
    assert (got == want).all()
    assert rep.failed_devices == [3]
    assert rep.switches > 0          # orphaned tiles were re-assigned


def test_all_devices_dead_raises():
    profile = HeterogeneityProfile.homogeneous(2)
    cluster = SimulatedCluster(profile)
    tiles = [np.ones(10, np.int64)] * 4
    with pytest.raises(RuntimeError):
        cluster.run(word_count_job(2), tiles,
                    failures=[FailureEvent(0, 0.0), FailureEvent(1, 0.0)])


def test_failure_slows_makespan():
    rng = np.random.default_rng(1)
    tiles = [rng.integers(0, 8, 100) for _ in range(32)]
    profile = HeterogeneityProfile.homogeneous(4, 100.0)
    c1 = SimulatedCluster(profile.copy())
    _, rep_ok = c1.run(word_count_job(8), tiles)
    c2 = SimulatedCluster(profile.copy())
    _, rep_fail = c2.run(word_count_job(8), tiles,
                         failures=[FailureEvent(device=0, at_time=rep_ok.makespan / 2)])
    assert rep_fail.makespan >= rep_ok.makespan


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=1, max_size=30),
       st.integers(0, 2**31 - 1))
def test_combiner_associativity(values, seed):
    """Combine in two different groupings -> same result."""
    job = word_count_job(101)
    tiles = [np.array([v]) for v in values]
    left = job.zero_fn()
    for t in tiles:
        left = job.combine_fn(left, job.map_fn(t))
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(tiles))
    right = job.zero_fn()
    for i in order:
        right = job.combine_fn(right, job.map_fn(tiles[i]))
    assert (left == right).all()


def test_energy_accounting_present():
    profile = HeterogeneityProfile.paper()
    cluster = SimulatedCluster(profile, power=PowerModel.cpu(profile))
    tiles = [np.ones(10, np.int64)] * 8
    _, rep = cluster.run(word_count_job(2), tiles)
    assert rep.energy_j is not None and rep.energy_j > 0
