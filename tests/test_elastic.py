"""Elastic resize integration: restore a checkpoint onto a DIFFERENT mesh
with re-sharding (subprocess: needs 8 placeholder devices)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r'''
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.checkpoint import store
from repro.checkpoint.elastic import restore_elastic
from repro.configs.base import get_config
from repro.distributed import meshes as M
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T

cfg = get_config("granite-3-8b", smoke=True)
params = T.init_params(cfg, jax.random.PRNGKey(0))

# save from a (2,4) mesh-sharded state
mesh1 = make_test_mesh()
sh1 = M.named(M.param_pspecs(cfg, params, mesh1), mesh1)
params1 = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh1)
d = tempfile.mkdtemp()
store.save(d, 1, params1, extra={"step": 1})

# restore onto a (2,2,2) multipod mesh
mesh2 = make_test_mesh(multi_pod=True)
restored, extra = restore_elastic(d, params, cfg, mesh2)

ok_values = all(
    np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)))
# every leaf is addressable on the new mesh
ok_sharding = all(len(x.sharding.device_set) >= 1 and x.committed
                  for x in jax.tree_util.tree_leaves(restored))
print("RESULT" + json.dumps({"values": ok_values, "sharding": ok_sharding,
                             "step": extra["step"]}))
'''


def test_elastic_restore_across_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[6:])
    assert out["values"] and out["sharding"] and out["step"] == 1
