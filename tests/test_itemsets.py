"""Apriori correctness: exact equality with a brute-force oracle plus the
algorithm's structural invariants (hypothesis property tests)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.itemsets import (apriori, apriori_bruteforce,
                                 generate_candidates, itemsets_to_bitmap,
                                 support_counts_ref)
from repro.data.baskets import BasketConfig, generate_baskets


@st.composite
def transaction_dbs(draw):
    n_items = draw(st.integers(4, 16))
    n_tx = draw(st.integers(8, 120))
    seed = draw(st.integers(0, 2**31 - 1))
    density = draw(st.floats(0.05, 0.5))
    rng = np.random.default_rng(seed)
    return (rng.random((n_tx, n_items)) < density).astype(np.uint8)


@settings(max_examples=25, deadline=None)
@given(transaction_dbs(), st.floats(0.05, 0.6))
def test_apriori_matches_bruteforce(T, frac):
    min_sup = max(1, int(frac * len(T)))
    got = apriori(T, min_sup, n_tiles=4).supports
    want = apriori_bruteforce(T, min_sup, max_k=T.shape[1])
    assert got == want


@settings(max_examples=25, deadline=None)
@given(transaction_dbs(), st.floats(0.05, 0.6))
def test_downward_closure(T, frac):
    """Every subset of a frequent itemset is frequent with >= support."""
    min_sup = max(1, int(frac * len(T)))
    sup = apriori(T, min_sup, n_tiles=2).supports
    for itemset, s in sup.items():
        for i in range(len(itemset)):
            sub = itemset[:i] + itemset[i + 1:]
            if sub:
                assert sub in sup
                assert sup[sub] >= s


def test_candidate_generation_classic():
    freq2 = [(0, 1), (0, 2), (1, 2), (1, 3)]
    cands = generate_candidates(freq2)
    # (0,1,2) joinable and all 2-subsets frequent; (1,2,3) pruned: (2,3) infrequent
    assert cands == [(0, 1, 2)]


def test_support_counts_ref_exact():
    T = np.array([[1, 1, 0, 1], [1, 0, 0, 1], [0, 1, 1, 0]], np.uint8)
    C = itemsets_to_bitmap([(0,), (0, 3), (1, 2), (0, 1, 3)], 4)
    got = np.asarray(support_counts_ref(T, C))
    assert got.tolist() == [2, 2, 1, 1]


def test_structured_baskets_find_patterns():
    """The synthetic generator's planted patterns must surface as frequent."""
    cfg = BasketConfig(n_tx=2000, n_items=40, n_patterns=3, pattern_len=3,
                       pattern_prob=0.5, seed=7)
    T = generate_baskets(cfg)
    res = apriori(T, min_support=60)
    assert res.levels >= 2
    assert any(len(s) >= 2 for s in res.supports)
