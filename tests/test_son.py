"""SON out-of-core plane: bit-identity vs the single-shot pipeline
(dense + sparse, apriori + eclat, static AND dynamic), kill-at-every-
partition-boundary resume parity, and the checkpoint-store crash-window
regressions the resume contract depends on."""
import dataclasses
import os
import shutil

import numpy as np
import pytest

import jax

from repro.checkpoint import store
from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets, sparse_baskets
from repro.data.sparse import SparseSlab, density_stats
from repro.mining import (SONConfig, SONKilled, SONMiner, local_min_support,
                          make_miner, partition_stats)
from repro.mining.son import partition_slices
from repro.pipeline import MarketBasketPipeline, PipelineConfig

ROWS = 64          # partition size → 3 partitions on the 192-row corpora


def dense_corpus():
    return generate_baskets(BasketConfig(n_tx=192, n_items=24, seed=1))


def sparse_corpus():
    # item frequencies well above the global threshold used below: SON's
    # per-partition threshold floor(G * rows / n_tx) must stay >= 2, or
    # pass 1 degenerates into mining every subset of every transaction
    # (a real SON failure mode for min_support ~ 1/partition_rows, not a
    # regime the out-of-core plane targets)
    return SparseSlab.from_baskets(
        sparse_baskets(192, 256, seed=2, max_item_freq=0.15), n_items=256)


def pipeline_config(algorithm="apriori", policy="static", min_support=0.05):
    return PipelineConfig(min_support=min_support, algorithm=algorithm,
                          policy=policy, n_tiles=4)


def single_shot(T, cfg):
    """The oracle: one in-core Apriori pipeline over the whole corpus."""
    oracle = dataclasses.replace(cfg, algorithm="apriori", policy="static")
    return MarketBasketPipeline(HeterogeneityProfile.paper(), oracle).run(T)


def son_run(T, cfg, workdir, **kw):
    son = SONConfig(workdir=str(workdir), partition_rows=ROWS, **kw)
    miner, _ = make_miner(T, config=cfg, son=son)
    return miner.run(T), miner


# ---------------------------------------------------------------------------
# checkpoint store crash-window regressions
# ---------------------------------------------------------------------------

def _tree(v=0):
    return {"a": np.arange(6, dtype=np.int64) + v,
            "b": np.full((2, 3), float(v), np.float32)}


class Boom(RuntimeError):
    pass


def test_save_crash_between_renames_keeps_previous_checkpoint(
        tmp_path, monkeypatch):
    """A crash after the old step is renamed aside but before the new dir
    lands must leave the previous checkpoint restorable (the old code did
    rmtree-then-rename: that window lost every checkpoint at once)."""
    d = str(tmp_path)
    store.save(d, 1, _tree(1), extra={"v": 1}, codec="raw")
    real_rename = os.rename

    def crashing(src, dst):
        if src.endswith(".tmp"):        # the commit rename of the new dir
            raise Boom()
        return real_rename(src, dst)

    monkeypatch.setattr(store.os, "rename", crashing)
    with pytest.raises(Boom):
        store.save(d, 1, _tree(2), extra={"v": 2}, codec="raw")
    monkeypatch.undo()

    assert store.latest_step(d) == 1
    restored, extra = store.restore(d, _tree())
    assert extra["v"] == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), _tree(1)["a"])
    # the next save heals the crashed layout and commits normally
    store.save(d, 1, _tree(3), extra={"v": 3}, codec="raw")
    _, extra = store.restore(d, _tree())
    assert extra["v"] == 3
    assert not any(n.endswith((".tmp", ".old")) for n in os.listdir(d))


def test_stale_tmp_dir_wiped_not_reused(tmp_path):
    """A leftover .tmp from a crashed save must not leak its files into the
    next checkpoint (e.g. a stale zstd payload next to a new raw one)."""
    d = str(tmp_path)
    tmp = os.path.join(d, "step_000000001.tmp")
    os.makedirs(tmp)
    with open(os.path.join(tmp, "arrays.msgpack.zst"), "wb") as f:
        f.write(b"junk from a crashed zstd attempt")
    step_dir = store.save(d, 1, _tree(1), codec="raw")
    assert sorted(os.listdir(step_dir)) == ["arrays.msgpack", "manifest.json"]
    _, _ = store.restore(d, _tree())


def test_keep_last_retention_prunes_oldest(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        store.save(d, s, _tree(s), codec="raw", keep_last=2)
    assert store.steps_present(d) == [4, 5]
    assert store.latest_step(d) == 5
    _, _ = store.restore(d, _tree(), step=4)


def test_restore_missing_step_names_requested_and_present(tmp_path):
    d = str(tmp_path)
    store.save(d, 2, _tree(2), codec="raw")
    with pytest.raises(FileNotFoundError) as ei:
        store.restore(d, _tree(), step=7)
    assert "7" in str(ei.value) and "2" in str(ei.value)
    with pytest.raises(FileNotFoundError) as ei:
        store.restore(str(tmp_path / "empty"), _tree())
    assert "none" in str(ei.value)


def test_latest_step_ignores_dangling_pointer(tmp_path):
    """latest_step must not report a step whose directory was deleted —
    fall back to the newest checkpoint actually on disk."""
    d = str(tmp_path)
    store.save(d, 1, _tree(1), extra={"v": 1}, codec="raw")
    store.save(d, 3, _tree(3), codec="raw")
    shutil.rmtree(os.path.join(d, "step_000000003"))
    assert store.latest_step(d) == 1
    _, extra = store.restore(d, _tree())
    assert extra["v"] == 1


# ---------------------------------------------------------------------------
# SON partition math
# ---------------------------------------------------------------------------

def test_local_threshold_floor_guarantees_no_false_negatives():
    # sum of the per-partition floors never exceeds the global threshold:
    # an itemset below the local bound everywhere is below G globally
    for n_tx, rows, G in [(192, 64, 10), (1000, 128, 37), (97, 10, 5)]:
        parts = partition_slices(n_tx, rows)
        total = sum(local_min_support(G, hi - lo, n_tx) - 1
                    for lo, hi in parts)
        assert total < G
        assert all(local_min_support(G, hi - lo, n_tx) >= 1
                   for lo, hi in parts)


def test_partition_stats_scales_features():
    stats = density_stats(dense_corpus())
    ps = partition_stats(stats, 64)
    assert ps.n_tx == 64 and ps.n_items == stats.n_items
    assert ps.nnz < stats.nnz
    np.testing.assert_array_equal(
        ps.item_counts, (stats.item_counts * (64 / stats.n_tx)).astype(int))


# ---------------------------------------------------------------------------
# bit-identity vs the single-shot pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["static", "dynamic"])
@pytest.mark.parametrize("dataset,algorithm,min_support", [
    ("dense", "apriori", 0.05),
    ("dense", "eclat", 0.05),
    ("sparse", "apriori", 0.08),
    ("sparse", "eclat", 0.08),
])
def test_son_matches_single_shot(tmp_path, dataset, algorithm, min_support,
                                 policy):
    T = dense_corpus() if dataset == "dense" else sparse_corpus()
    cfg = pipeline_config(algorithm, policy, min_support)
    oracle = single_shot(T, cfg)
    assert oracle.supports, "oracle mined nothing — corpus too sparse"
    result, _ = son_run(T, cfg, tmp_path)
    assert result.supports == oracle.supports
    assert result.rules == oracle.rules
    assert result.report.execution == "out_of_core"
    assert result.report.n_partitions == len(partition_slices(
        density_stats(T).n_tx, ROWS))
    assert result.report.partitions_resumed == 0


def test_auto_selects_one_global_algorithm(tmp_path):
    T = dense_corpus()
    cfg = pipeline_config("auto")
    result, miner = son_run(T, cfg, tmp_path)
    assert miner.algorithm_choice is not None
    assert result.report.algorithm == miner.algorithm_choice.algorithm
    oracle = single_shot(T, cfg)
    assert result.supports == oracle.supports
    assert result.rules == oracle.rules


# ---------------------------------------------------------------------------
# kill-and-resume
# ---------------------------------------------------------------------------

def test_kill_at_every_partition_boundary_resumes_bit_identical(tmp_path):
    T = dense_corpus()
    cfg = pipeline_config()
    base, _ = son_run(T, cfg, tmp_path / "base")
    n_boundaries = 2 * base.report.n_partitions
    for n in range(1, n_boundaries + 1):
        wd = tmp_path / f"kill{n}"
        with pytest.raises(SONKilled) as ei:
            son_run(T, cfg, wd, abort_after=n)
        assert ei.value.boundary == n
        resumed, _ = son_run(T, cfg, wd, resume=True)
        assert resumed.supports == base.supports, f"kill at boundary {n}"
        assert resumed.rules == base.rules, f"kill at boundary {n}"
        assert resumed.report.partitions_resumed == n


def test_ledger_prices_every_partition_and_checkpoint(tmp_path):
    T = dense_corpus()
    cfg = pipeline_config()
    result, _ = son_run(T, cfg, tmp_path)
    P = result.report.n_partitions
    names = [r.name for r in result.report.ledger.phases]
    for p in range(P):
        assert f"son-spill-p{p}" in names             # pass-0 spill write
        assert names.count(f"son-load-p{p}") == 2     # pass-1 + pass-2 loads
        assert any(n.startswith(f"son-p{p}/") for n in names)  # local pass
        assert f"son-recount-p{p}" in names           # global re-count
    ckpts = [n for n in names if n.startswith("son-ckpt-b")]
    assert len(ckpts) == 2 * P == result.report.checkpoint_saves
    assert result.report.checkpoint_bytes > 0
    assert all(r.sim_time_s > 0 and r.energy_j > 0
               for r in result.report.ledger.phases)
    assert "mba-rules" in names


def test_resume_rejects_mismatched_job(tmp_path):
    T = dense_corpus()
    with pytest.raises(SONKilled):
        son_run(T, pipeline_config(min_support=0.05), tmp_path, abort_after=2)
    with pytest.raises(ValueError, match="fingerprint"):
        son_run(T, pipeline_config(min_support=0.10), tmp_path, resume=True)


def test_resume_without_spill_errors(tmp_path):
    with pytest.raises(FileNotFoundError, match="resume"):
        son_run(dense_corpus(), pipeline_config(), tmp_path / "nothing",
                resume=True)


# ---------------------------------------------------------------------------
# sharded local pass + mid-partition device loss (multi-device CI leg)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device mesh (set XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_device_loss_mid_partition_triggers_shard_replan(tmp_path):
    from repro.distributed.fault import FaultEvent, FaultPlan
    from repro.distributed.mining import make_shard_mesh

    T = dense_corpus()
    cfg = pipeline_config()
    miner = SONMiner(config=cfg,
                     son=SONConfig(workdir=str(tmp_path), partition_rows=ROWS),
                     mesh=make_shard_mesh())
    faults = {1: FaultPlan([FaultEvent(2, "device_loss", 1)])}
    result = miner.run(T, faults)
    oracle = single_shot(T, cfg)
    assert result.supports == oracle.supports
    assert result.rules == oracle.rules
    assert result.report.replans >= 1
