"""End-to-end MarketBasketPipeline: oracle equality, data-plane agreement,
report invariants, ingestion parity, and failure accounting."""
import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori_bruteforce
from repro.core.mapreduce import FailureEvent
from repro.core.rules import generate_rules
from repro.core.itemsets import AprioriResult
from repro.data.baskets import BasketConfig, generate_baskets, pack_transactions
from repro.pipeline import MarketBasketPipeline, PipelineConfig


def small_db(n_tx=300, n_items=24, seed=5):
    return generate_baskets(BasketConfig(n_tx=n_tx, n_items=n_items,
                                         n_patterns=4, pattern_len=3,
                                         pattern_prob=0.5, seed=seed))


def test_end_to_end_matches_bruteforce_oracle():
    T = small_db()
    cfg = PipelineConfig(min_support=0.05, min_confidence=0.6, n_tiles=4)
    res = MarketBasketPipeline(config=cfg).run(T)

    min_sup = cfg.abs_support(len(T))
    want = apriori_bruteforce(T, min_sup, max_k=T.shape[1])
    assert res.supports == want

    # rules must equal direct generation over the oracle supports
    oracle = AprioriResult(supports=want, n_tx=len(T), levels=0)
    want_rules = generate_rules(oracle, 0.6, min_lift=0.0)
    assert res.rules == want_rules
    assert res.report.n_rules == len(want_rules)


def test_pallas_and_ref_data_planes_agree():
    T = small_db(seed=11)
    base = dict(min_support=0.05, n_tiles=4)
    ref = MarketBasketPipeline(
        config=PipelineConfig(data_plane="ref", **base)).run(T)
    pallas = MarketBasketPipeline(
        config=PipelineConfig(data_plane="pallas", interpret=True,
                              **base)).run(T)
    assert pallas.report.backend == "pallas"
    assert ref.report.backend == "ref"
    assert pallas.supports == ref.supports
    assert pallas.rules == ref.rules


def test_report_tile_counts_sum_to_job_size():
    T = small_db(n_tx=500, seed=2)
    res = MarketBasketPipeline(
        config=PipelineConfig(min_support=0.04, n_tiles=8)).run(T)
    rep = res.report
    assert rep.tiles_invariant_ok()
    for r in rep.rounds:
        assert sum(r.tiles_per_device) == r.n_tiles
        # every counting round spreads work across the paper's four cores
        assert len(r.tiles_per_device) == 4


def test_report_accounting_nonzero():
    T = small_db(n_tx=400, seed=3)
    res = MarketBasketPipeline(
        config=PipelineConfig(min_support=0.05, n_tiles=4)).run(T)
    rep = res.report
    assert rep.n_rounds >= 2
    assert rep.total_time_s > 0
    assert rep.total_energy_j > 0
    assert rep.n_itemsets == len(res.supports) > 0
    # serial phases gate every core except the chosen one
    for r in rep.rounds:
        if r.serial is not None:
            assert r.serial.device not in r.serial.gated
            assert len(r.serial.gated) == 3
            assert r.serial.energy_j > 0
    # candidate batches are bucketed to kernel lane multiples
    for m in rep.kernel_batches:
        assert m % 128 == 0
    assert "rounds" in rep.summary() or "round" in rep.summary()


def test_ingestion_from_transaction_lists():
    T = small_db(seed=7)
    tx_lists = [list(np.nonzero(row)[0]) for row in T]
    cfg = PipelineConfig(min_support=0.05, n_tiles=4)
    from_bitmap = MarketBasketPipeline(config=cfg).run(T)
    from_lists = MarketBasketPipeline(config=cfg).run(tx_lists)
    assert from_lists.supports == from_bitmap.supports
    assert from_lists.rules == from_bitmap.rules


def test_pack_transactions_sets_semantics():
    T = pack_transactions([[0, 2, 2], [], [1]], n_items=4)
    assert T.tolist() == [[1, 0, 1, 0], [0, 0, 0, 0], [0, 1, 0, 0]]
    with pytest.raises(ValueError):
        pack_transactions([[0, -1]], n_items=4)
    with pytest.raises(ValueError):
        pack_transactions([[0, 7]], n_items=4)


def test_report_uses_raw_shapes_and_fraction_boundary():
    T = small_db(n_tx=200, n_items=20, seed=1)   # pads 20 -> 128 internally
    cfg = PipelineConfig(min_support=0.05, n_tiles=4)
    rep = MarketBasketPipeline(config=cfg).run(T).report
    assert rep.n_items == 20
    assert rep.n_tx == 200
    assert rep.rounds[0].n_candidates == 20
    # min_support == 1.0 means "in every transaction", not absolute 1
    assert PipelineConfig(min_support=1.0).abs_support(200) == 200
    assert PipelineConfig(min_support=50).abs_support(200) == 50


def test_failure_replan_keeps_result_and_counts_switches():
    T = small_db(n_tx=400, seed=9)
    cfg = PipelineConfig(min_support=0.05, n_tiles=8)
    clean = MarketBasketPipeline(config=cfg).run(T)
    failed = MarketBasketPipeline(config=cfg).run(
        T, failures=[FailureEvent(device=3, at_time=0.0)])
    # the dead core's tiles are re-planned: same answer, switches charged
    assert failed.supports == clean.supports
    assert failed.report.total_switches > 0
    assert failed.report.total_energy_j != clean.report.total_energy_j
    # tiles_per_device reflects execution: the dead core ran nothing, the
    # survivors ran everything, and the job-size invariant still holds
    for r in failed.report.rounds:
        if r.n_tiles:
            assert r.tiles_per_device[3] == 0
            assert sum(r.tiles_per_device) == r.n_tiles


def test_non_binary_bitmap_rejected_before_cast():
    pipe = MarketBasketPipeline(config=PipelineConfig(min_support=0.2,
                                                      n_tiles=2))
    with pytest.raises(ValueError):
        pipe.run(np.array([[2, 0], [0, 1]]))          # counts, not bits
    with pytest.raises(ValueError):
        pipe.run(np.array([[0.9, 0.0], [0.9, 0.9]]))  # floats truncate to 0
    with pytest.raises(ValueError):
        pipe.run(np.ones(8, np.uint8))                # 1-D


def test_failure_energy_bills_replanned_core_as_active():
    """A planned-idle core that executes orphaned tiles must be charged
    active watts, and the dead core gated watts (zero busy seconds)."""
    T = small_db(n_tx=400, seed=9)
    cfg = PipelineConfig(min_support=0.05, n_tiles=2)
    res = MarketBasketPipeline(config=cfg).run(
        T, failures=[FailureEvent(device=3, at_time=0.0)])
    for r in res.report.rounds:
        if r.n_tiles:
            # dead core executed nothing; survivors ran every tile
            assert r.map_busy_s[3] == 0.0
            assert sum(1 for b in r.map_busy_s if b > 0) >= 1
            assert r.energy_j > 0


def test_midround_death_charges_gated_tail_not_idle():
    """A core that dies after finishing some tiles is active for its busy
    seconds and gated — not idle — for the rest of the round."""
    T = small_db(n_tx=400, seed=9)
    cfg = PipelineConfig(min_support=0.05, n_tiles=8)
    pipe = MarketBasketPipeline(config=cfg)
    # death late enough that core 3 completes at least one tile first
    # (tiles are 50 rows x 128 padded items = 6400 work units; core 3 runs
    # at speed 400 => 16 simulated seconds per tile)
    res = pipe.run(T, failures=[FailureEvent(device=3, at_time=20.0)])
    rounds = [r for r in res.report.rounds
              if 3 in r.failed_devices and r.map_busy_s[3] > 0]
    assert rounds, "expected core 3 to die mid-round with work done"
    r = rounds[0]
    # recompute what idle-tail billing would have charged: must be more
    # (idle watts exceed gated watts in the cpu calibration)
    power = pipe.power
    idle_billing = power.energy(
        np.array(r.map_busy_s), r.map_makespan_s,
        gated=[d for d, b in enumerate(r.map_busy_s) if b == 0.0],
        switches=r.switches + r.reissued)   # every migration is priced
    assert r.energy_j < idle_billing


def test_preused_scheduler_switch_counter_not_recounted():
    """A scheduler with prior rebalance history must not inflate per-round
    switch counts (ExecReport.switches is per-run; the scheduler's lifetime
    counter is tracked separately on the scheduler itself)."""
    from repro.core.scheduler import MBScheduler
    profile = HeterogeneityProfile.paper()
    sched = MBScheduler(profile)
    sched.switches = 5                      # pretend prior rebalances
    T = small_db(n_tx=300, seed=1)
    res = MarketBasketPipeline(
        profile, PipelineConfig(min_support=0.05, n_tiles=4),
        scheduler=sched).run(T)
    assert res.report.total_switches == 0   # clean run: no moves happened


def test_policy_equal_is_no_faster_than_lpt():
    T = small_db(n_tx=600, seed=4)
    times = {}
    for split in ("equal", "lpt"):
        res = MarketBasketPipeline(
            HeterogeneityProfile.paper(),
            PipelineConfig(min_support=0.05, n_tiles=16,
                           split=split)).run(T)
        times[split] = res.report.total_time_s
    assert times["lpt"] <= times["equal"] + 1e-9
