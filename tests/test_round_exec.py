"""Device-resident round execution: the transfer ledger is exact for a
scripted two-round mine, the pipelined path makes exactly one d2h sync per
counting round (the per_tile baseline makes one per tile), the on-device
candidate join/prune matches the host generate_candidates bit for bit
(including the guarded host fallback), and both execution modes mine
identical supports/rules on Apriori and Eclat."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.itemsets import (apriori_bruteforce, generate_candidates,
                                 itemsets_to_bitmap)
from repro.data.baskets import BasketConfig, generate_baskets
from repro.mining import EclatMiner
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.pipeline.dataplane import pad_candidates
from repro.pipeline.devgen import DeviceLattice


def _mk_cfg(**kw):
    base = dict(min_support=0.05, min_confidence=0.5, n_tiles=4,
                data_plane="ref")
    base.update(kw)
    return PipelineConfig(**base)


# ---------------------------------------------------------------------------
# ledger exactness: every byte and sync of a scripted 2-round mine
# ---------------------------------------------------------------------------

def test_two_round_mine_transfer_ledger_is_exact():
    T = generate_baskets(BasketConfig(n_tx=256, n_items=24, seed=3))
    cfg = _mk_cfg(max_k=2)
    res = MarketBasketPipeline(config=cfg).run(T)
    rounds = res.report.rounds
    assert len(rounds) == 2 and rounds[1].n_frequent > 0, \
        "fixture must mine two full rounds with surviving pairs"
    led = res.report.ledger
    by_name = {p.name: p for p in led.phases}
    n_items_pad = 128                       # 24 raw items, lane-padded
    f1 = rounds[0].n_frequent
    f1_cap = max(cfg.m_bucket, -(-f1 // cfg.m_bucket) * cfg.m_bucket)
    m_cap = rounds[1].m_padded
    f2 = rounds[1].n_frequent

    # round 1: the one-time tile upload stages here (256 uint8 rows), and
    # the single readback is the padded int64 item-count vector
    r1 = by_name["mba-round1-item-counts"]
    assert r1.h2d_bytes == 256 * n_items_pad
    assert r1.d2h_bytes == n_items_pad * 8
    assert r1.syncs == 1

    # candgen k=2: the frequent-item seed upload ([f1_cap, 1] int32) is
    # consumed here; the device join itself transfers nothing
    cg = by_name["mba-candgen-k2"]
    assert cg.h2d_bytes == f1_cap * 4
    assert cg.d2h_bytes == 0 and cg.syncs == 0

    # round 2: no upload (candidates were born on device); the one d2h is
    # the packed [m_cap + 1] int32 counts-plus-join-size vector
    r2 = by_name["mba-round2-support"]
    assert r2.h2d_bytes == 0
    assert r2.d2h_bytes == (m_cap + 1) * 4
    assert r2.syncs == 1

    # rules: one decode per mined level >= 2 — here one [f2, 2] int32 read
    ru = by_name["mba-rules"]
    assert ru.h2d_bytes == 0
    assert ru.d2h_bytes == f2 * 2 * 4
    assert ru.syncs == 1

    assert led.total_h2d_bytes == r1.h2d_bytes + cg.h2d_bytes
    assert led.total_d2h_bytes == (r1.d2h_bytes + r2.d2h_bytes
                                   + ru.d2h_bytes)
    assert led.total_syncs == 3


# ---------------------------------------------------------------------------
# the one-sync-per-round contract (asserted, not just benched)
# ---------------------------------------------------------------------------

def test_pipelined_syncs_once_per_round_per_tile_syncs_per_tile():
    T = generate_baskets(BasketConfig(n_tx=512, n_items=32, seed=5))
    runs = {}
    for rexec in ("pipelined", "per_tile"):
        res = MarketBasketPipeline(config=_mk_cfg(round_execution=rexec)
                                   ).run(T)
        maps = res.report.ledger.by_kind("map")
        assert maps, "mine must run at least one counting round"
        if rexec == "pipelined":
            assert all(p.syncs == 1 for p in maps), \
                [(p.name, p.syncs) for p in maps]
        else:
            assert all(p.syncs == p.n_tiles == 4 for p in maps), \
                [(p.name, p.syncs) for p in maps]
        runs[rexec] = res

    # both modes mine the same answer, and it is the oracle's
    want = apriori_bruteforce(T, max(1, int(0.05 * 512)), max_k=8)
    assert runs["pipelined"].supports == runs["per_tile"].supports == want
    assert runs["pipelined"].rules == runs["per_tile"].rules


def test_round_execution_knob_is_validated():
    with pytest.raises(ValueError):
        MarketBasketPipeline(config=_mk_cfg(round_execution="bogus"))


# ---------------------------------------------------------------------------
# on-device candidate generation vs the host reference
# ---------------------------------------------------------------------------

def _decoded(C, valid_c):
    Ch, v = np.asarray(C), np.asarray(valid_c)
    return [tuple(int(x) for x in row) for row in Ch[v]]


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("lat_kw", [{}, {"max_join_rows": 0}],
                         ids=["device-join", "host-fallback"])
def test_device_join_prune_matches_generate_candidates(seed, lat_kw):
    rng = np.random.default_rng(seed)
    n_items, min_sup = 16, 5
    lat = DeviceLattice(n_items, m_bucket=8, **lat_kw)
    items = np.sort(rng.choice(n_items, size=9, replace=False))
    lat.seed_items(items)
    frequent = [(int(i),) for i in items]
    expect_supports = {}
    for k in (2, 3, 4, 5):
        want = generate_candidates(frequent)
        gen = lat.join()
        if not want:
            # every pair pruned (or J = 0): both the device join — which
            # reads back the survivor count before sizing the round — and
            # the host fallback report the round dry
            assert gen is None
            break
        assert gen is not None
        C, valid_c, bitmap, m_cap = gen
        assert _decoded(C, valid_c) == want
        ref_bitmap = pad_candidates(itemsets_to_bitmap(want, n_items), m_cap)
        assert (np.asarray(bitmap) == ref_bitmap).all()

        # fabricate this round's counts and close it through the real
        # finalize/advance protocol (order is positional — the invariant
        # the device join guarantees)
        counts = rng.integers(0, 10, size=len(want))
        acc = jnp.zeros(m_cap, jnp.int32).at[:len(want)].set(
            jnp.asarray(counts, jnp.int32))
        packed, Fn, vn = lat.finalize(acc, C, valid_c, min_sup)
        m_true, f_true = lat.advance(np.asarray(packed), Fn, vn, min_sup)
        frequent = [c for c, s in zip(want, counts) if s >= min_sup]
        assert m_true == len(want) and f_true == len(frequent)
        expect_supports.update(
            {c: int(s) for c, s in zip(want, counts) if s >= min_sup})
        if not frequent:
            break
    assert lat.decode_supports() == expect_supports


# ---------------------------------------------------------------------------
# cross-mode parity on both algorithms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["apriori", "eclat"])
@pytest.mark.parametrize("policy", ["static", "dynamic"])
def test_both_modes_mine_identically(algorithm, policy):
    T = generate_baskets(BasketConfig(n_tx=384, n_items=28, seed=9))
    results = []
    for rexec in ("pipelined", "per_tile"):
        cfg = _mk_cfg(algorithm=algorithm, policy=policy,
                      round_execution=rexec)
        miner = (EclatMiner(config=cfg) if algorithm == "eclat"
                 else MarketBasketPipeline(config=cfg))
        results.append(miner.run(T))
    want = apriori_bruteforce(T, max(1, int(0.05 * 384)), max_k=8)
    assert results[0].supports == results[1].supports == want
    assert results[0].rules == results[1].rules
