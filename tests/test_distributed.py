"""Distributed runtime: shard_map MapReduce on 8 placeholder devices,
ring all-gather vs reference, fault/straggler policies, sharding rules."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.distributed.fault import (FaultEvent, FaultPlan, RestartPolicy,
                                     detect_stragglers)
from repro.core.hetero import HeterogeneityProfile

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.mapreduce import MapReduceJob, run_sharded
from repro.distributed.collectives import ring_all_gather, hierarchical_psum
from repro.launch.mesh import make_test_mesh
from jax.experimental.shard_map import shard_map

mesh = make_test_mesh()  # (2 data, 4 model)
out = {}

# 1. shard_map mapreduce == sequential (run_sharded returns (result, report))
data = jnp.asarray(np.random.default_rng(0).integers(0, 16, (64,)), jnp.int32)
job = MapReduceJob("wc",
    map_fn=lambda x: jnp.bincount(x, length=16),
    combine_fn=lambda a, b: a + b,
    zero_fn=lambda: jnp.zeros(16, jnp.int32))
got, rep = run_sharded(job, data, mesh, axis="data")
want = jnp.bincount(data, length=16)
out["mapreduce_sharded_ok"] = bool((got == want).all())
out["mapreduce_sharded_report_ok"] = rep.makespan >= 0.0

# 2. ring all-gather == lax.all_gather
x = jnp.arange(8.0).reshape(4, 2)
def body(xs):
    ring = ring_all_gather(xs, "model")
    ref = jax.lax.all_gather(xs, "model").reshape(ring.shape)
    return (jnp.abs(ring - ref) < 1e-6).all()
ok = shard_map(body, mesh=mesh, in_specs=(P("model", None),), out_specs=P(),
               check_rep=False)(x)
out["ring_allgather_ok"] = bool(ok)

# 3. hierarchical psum == flat psum on multipod mesh
mesh2 = make_test_mesh(multi_pod=True)  # pod, data, model
y = jnp.arange(8.0)
def body2(ys):
    h = hierarchical_psum(ys, "data", "pod")
    f = jax.lax.psum(ys, ("pod", "data"))
    return (jnp.abs(h - f) < 1e-6).all()
ok2 = shard_map(body2, mesh=mesh2, in_specs=(P(("pod", "data")),),
                out_specs=P(), check_rep=False)(y)
out["hier_psum_ok"] = bool(ok2)

# 4. int8 quantized psum ~= f32 psum
from repro.optim.compression import psum_int8
g = jnp.asarray(np.random.default_rng(1).standard_normal(16), jnp.float32)
def body3(gs):
    approx = psum_int8(gs, "data")
    exact = jax.lax.psum(gs, "data")
    scale = jnp.max(jnp.abs(exact)) + 1e-9
    return (jnp.abs(approx - exact) / scale < 0.05).all()
ok3 = shard_map(body3, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                check_rep=False)(g)
out["int8_psum_ok"] = bool(ok3)

print("RESULT" + json.dumps(out))
'''


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_sharded_mapreduce(dist_results):
    assert dist_results["mapreduce_sharded_ok"]


def test_ring_all_gather(dist_results):
    assert dist_results["ring_allgather_ok"]


def test_hierarchical_psum(dist_results):
    assert dist_results["hier_psum_ok"]


def test_int8_quantized_psum(dist_results):
    assert dist_results["int8_psum_ok"]


# ---- host-side fault policy tests (no devices needed) ----

def test_detect_stragglers():
    times = np.array([1.0, 1.1, 0.9, 5.0])
    assert detect_stragglers(times, threshold=2.0) == [3]


def test_restart_policy_elastic_shrink():
    prof = HeterogeneityProfile.homogeneous(4)
    pol = RestartPolicy(max_restarts=2)
    p2 = pol.on_device_loss(prof, 1)
    assert p2.n == 3
    with pytest.raises(RuntimeError):
        pol.on_device_loss(p2, 0), pol.on_device_loss(p2, 0)
        pol.on_device_loss(p2, 0)


def test_straggler_observation_reduces_share():
    prof = HeterogeneityProfile.homogeneous(4, 10.0)
    pol = RestartPolicy()
    p2 = pol.on_straggler(prof, 2, slowdown=8.0)
    assert p2.speeds[2] < 10.0


def test_fault_plan_lookup():
    fp = FaultPlan([FaultEvent(3, "device_loss", 1),
                    FaultEvent(3, "straggler", 0, 2.0)])
    assert len(fp.at(3)) == 2 and fp.at(4) == []
