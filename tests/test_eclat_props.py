"""Property tests for the vertical-mining plane: Eclat vs the bruteforce
Apriori oracle on random corpora, the sparse slab round trip, and the
packed tid-column layout, under EXACT equality throughout."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori_bruteforce
from repro.data.sparse import SparseSlab, pack_tid_columns
from repro.mining import EclatMiner
from repro.pipeline import PipelineConfig

# sampled (not arbitrary) dims: each distinct padded shape is a fresh XLA
# compile, so draw from a small lattice that still crosses the word and
# lane boundaries
_N_TX = (1, 31, 32, 33, 100)
_N_ITEMS = (1, 8, 33, 40)


@st.composite
def corpora(draw):
    n = draw(st.sampled_from(_N_TX))
    i = draw(st.sampled_from(_N_ITEMS))
    density = draw(st.sampled_from([0.1, 0.4, 0.8]))
    seed = draw(st.integers(0, 2**31 - 1))
    T = (np.random.default_rng(seed).random((n, i)) < density).astype(np.uint8)
    return T


@settings(max_examples=12, deadline=None)
@given(corpora(), st.sampled_from([0.1, 0.3, 0.6]))
def test_eclat_matches_bruteforce(T, min_support):
    cfg = PipelineConfig(min_support=min_support, n_tiles=4, max_k=4)
    res = EclatMiner(HeterogeneityProfile.paper(), cfg).run(T)
    want = apriori_bruteforce(T, cfg.abs_support(T.shape[0]), max_k=4)
    assert res.supports == want


@settings(max_examples=25, deadline=None)
@given(corpora())
def test_sparse_slab_round_trip(T):
    slab = SparseSlab.from_dense(T)
    np.testing.assert_array_equal(slab.to_dense(), T)
    assert slab.nnz == int(T.sum())
    np.testing.assert_array_equal(slab.item_counts(), T.sum(axis=0))


@settings(max_examples=25, deadline=None)
@given(corpora())
def test_tid_columns_bit_layout(T):
    """Column i, word w, bit b <=> transaction 32w+b holds item i — and the
    padding region beyond the true rows/words stays all-zero (the kernels
    rely on inert padding)."""
    cols = SparseSlab.from_dense(T).tid_columns()
    np.testing.assert_array_equal(cols, pack_tid_columns(T))
    n, i = T.shape
    unpacked = np.unpackbits(cols.view(np.uint8), axis=1, bitorder="little")
    np.testing.assert_array_equal(unpacked[:i, :n], T.T)
    assert not unpacked[i:, :].any()
    assert not unpacked[:, n:].any()
