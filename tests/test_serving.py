"""Serving plane: batched top-k vs brute-force oracle, Pallas/ref agreement,
cache + refresh accounting, index determinism and persistence, report
invariants."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.itemsets import apriori
from repro.core.rules import generate_rules
from repro.data.baskets import BasketConfig, generate_baskets
from repro.kernels.rule_match.ops import rule_topk
from repro.kernels.rule_match.ref import recommend_ref
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.serving import (Query, RecommendationEngine, RuleIndex,
                           ServingConfig, recommend_bruteforce)


@pytest.fixture(scope="module")
def mined():
    """One small mined corpus shared by the engine tests."""
    T = generate_baskets(BasketConfig(n_tx=500, n_items=32, n_patterns=5,
                                      pattern_len=3, pattern_prob=0.5,
                                      seed=3))
    res = MarketBasketPipeline(
        config=PipelineConfig(min_support=0.05, min_confidence=0.5,
                              n_tiles=4)).run(T)
    assert res.rules, "fixture corpus must mine a non-trivial rule set"
    return T, res


def queries_of(T, n):
    return [Query.of(list(np.nonzero(row)[0])) for row in T[:n]]


# ---------------------------------------------------------------------------
# kernel family: ops wrapper (Pallas interpret) vs pure-jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,I,R,k", [(5, 40, 17, 3), (8, 128, 128, 5),
                                     (1, 33, 7, 1), (12, 64, 150, 4)])
def test_rule_topk_pallas_matches_ref_oracle(B, I, R, k):
    rng = np.random.default_rng(B * I + R)
    Q = (rng.random((B, I)) < 0.3).astype(np.uint8)
    A = np.zeros((R, I), np.uint8)
    for m in range(R):
        A[m, rng.choice(I, size=rng.integers(1, 4), replace=False)] = 1
    sizes = A.sum(1).astype(np.float32)
    conf = rng.random(R).astype(np.float32)
    cons = rng.integers(0, I, R).astype(np.int32)

    got_i, got_s = rule_topk(Q, A, sizes, conf, cons, k=k, n_items=I,
                             backend="pallas", interpret=True)
    # hand-pad for the pure ref oracle (the same contract ops applies)
    Ip = I + (-I) % 128
    Rp = R + (-R) % 128
    Qp = np.pad(Q, ((0, (-B) % 8), (0, Ip - I)))
    Ap = np.pad(A, ((0, Rp - R), (0, Ip - I)))
    want_i, want_s = recommend_ref(
        jnp.asarray(Qp, jnp.int8), jnp.asarray(Ap, jnp.int8),
        jnp.asarray(np.pad(sizes, (0, Rp - R), constant_values=-1)),
        jnp.asarray(np.pad(conf, (0, Rp - R))),
        jnp.asarray(np.pad(cons, (0, Rp - R), constant_values=Ip)), I, k)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i)[:B])
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s)[:B])


def test_rule_topk_padded_rows_never_match():
    # an all-zero antecedent row would subset-match everything if the
    # padding contract (sizes = -1) were broken
    Q = np.ones((2, 16), np.uint8)
    A = np.zeros((1, 16), np.uint8)
    A[0, 3] = 1
    items, scores = rule_topk(Q, A, np.array([1.0], np.float32),
                              np.array([0.9], np.float32),
                              np.array([5], np.int32), k=2, n_items=16,
                              backend="ref")
    # item 5 is already in every basket -> excluded; nothing else scores
    assert (np.asarray(scores) <= 0).all()


# ---------------------------------------------------------------------------
# engine: batched top-k == brute-force oracle, plane agreement
# ---------------------------------------------------------------------------

def test_engine_matches_bruteforce_oracle(mined):
    T, res = mined
    index = RuleIndex.build(res.rules, T.shape[1])
    engine = RecommendationEngine(
        index, config=ServingConfig(k=4, batch_buckets=(1, 8),
                                    data_plane="ref"))
    queries = queries_of(T, 60)
    results, report = engine.serve(queries)
    assert report.n_queries == len(queries)
    for q, got in zip(queries, results):
        assert got == recommend_bruteforce(res.rules, q.payload, 4)
        assert len(got) <= 4
        for item, score in got:
            assert item not in q.payload and score > 0


def test_engine_pallas_and_ref_planes_agree(mined):
    T, res = mined
    index = RuleIndex.build(res.rules, T.shape[1])
    queries = queries_of(T, 16)
    base = dict(k=4, batch_buckets=(8,), cache_size=0)
    ref = RecommendationEngine(
        index, config=ServingConfig(data_plane="ref", **base))
    pallas = RecommendationEngine(
        index, config=ServingConfig(data_plane="pallas", interpret=True,
                                    **base))
    r_ref, rep_ref = ref.serve(queries)
    r_pal, rep_pal = pallas.serve(queries)
    assert rep_ref.backend == "ref" and rep_pal.backend == "pallas"
    assert r_ref == r_pal


def test_engine_accepts_bitmap_and_id_list_queries(mined):
    T, res = mined
    engine = RecommendationEngine(RuleIndex.build(res.rules, T.shape[1]),
                                  config=ServingConfig(k=3,
                                                       data_plane="ref"))
    from_rows, _ = engine.serve([Query.of(row) for row in T[:10]])
    from_ids, _ = engine.serve(queries_of(T, 10))
    assert from_rows == from_ids
    with pytest.raises(ValueError):
        engine.recommend(Query.of([T.shape[1] + 5]))    # id out of range
    with pytest.raises(ValueError):
        engine.serve([Query.of(np.full(T.shape[1], 2, np.uint8))])
    padded = np.zeros(engine.index.n_items_padded, np.uint8)
    padded[engine.index.n_items + 1] = 1            # bit in the lane padding
    with pytest.raises(ValueError):
        engine.serve([Query.of(padded)])
    with pytest.raises(TypeError):
        engine.serve([list(np.nonzero(T[0])[0])])   # bare payload: removed
    with pytest.raises(TypeError):
        engine.submit(T[0])                         # bare bitmap row: removed


# ---------------------------------------------------------------------------
# cache: hit/miss accounting, refresh invalidation
# ---------------------------------------------------------------------------

def test_cache_hits_and_refresh_invalidation(mined):
    T, res = mined
    index = RuleIndex.build(res.rules, T.shape[1])
    engine = RecommendationEngine(
        index, config=ServingConfig(k=4, data_plane="ref", cache_size=256))
    queries = queries_of(T, 20)
    first, rep1 = engine.serve(queries)
    assert rep1.cache_misses > 0
    again, rep2 = engine.serve(queries)
    assert again == first
    assert rep2.cache_hits == len(queries) and rep2.cache_misses == 0
    # refresh swaps the index, bumps the version and drops every entry
    v0 = engine.index.version
    engine.refresh(RuleIndex.build(res.rules, T.shape[1]))
    assert engine.index.version > v0
    _, rep3 = engine.serve(queries)
    assert rep3.cache_hits == 0 and rep3.cache_misses == len(queries)


def test_cache_disabled_still_correct(mined):
    T, res = mined
    index = RuleIndex.build(res.rules, T.shape[1])
    engine = RecommendationEngine(
        index, config=ServingConfig(k=4, data_plane="ref", cache_size=0))
    queries = queries_of(T, 10) * 2                 # repeats cannot hit
    results, rep = engine.serve(queries)
    assert rep.cache_hits == 0 and rep.cache_misses == len(queries)
    assert results[:10] == results[10:]


def test_cache_lru_eviction():
    from repro.serving.cache import ResultCache, basket_key
    cache = ResultCache(maxsize=2)
    keys = [basket_key(np.eye(8, dtype=np.uint8)[i]) for i in range(3)]
    for i, key in enumerate(keys):
        cache.put(key, [(i, 1.0)])
    assert cache.get(keys[0]) is None               # evicted, counted as miss
    assert cache.get(keys[2]) == [(2, 1.0)]
    assert cache.hits == 1 and cache.misses == 1


# ---------------------------------------------------------------------------
# index: deterministic build, save -> load -> identical recommendations
# ---------------------------------------------------------------------------

def test_index_build_is_order_invariant(mined):
    T, res = mined
    shuffled = list(res.rules)
    np.random.default_rng(0).shuffle(shuffled)
    a = RuleIndex.build(res.rules, T.shape[1])
    b = RuleIndex.build(shuffled, T.shape[1])
    assert a.same_arrays(b)
    assert a.n_rows == b.n_rows > 0
    assert a.n_rows_padded % 128 == 0 and a.n_items_padded % 128 == 0


def test_index_save_load_identical_recommendations(tmp_path, mined):
    T, res = mined
    index = RuleIndex.build(res.rules, T.shape[1], version=3)
    index.save(str(tmp_path))
    loaded = RuleIndex.load(str(tmp_path))
    assert loaded.same_arrays(index)
    assert (loaded.n_rows, loaded.n_rules, loaded.n_items, loaded.version) \
        == (index.n_rows, index.n_rules, index.n_items, 3)
    queries = queries_of(T, 12)
    cfg = ServingConfig(k=4, data_plane="ref")
    a, _ = RecommendationEngine(index, config=cfg).serve(queries)
    b, _ = RecommendationEngine(loaded, config=cfg).serve(queries)
    assert a == b


def test_index_rejects_bad_inputs(mined):
    _, res = mined
    with pytest.raises(ValueError):
        RuleIndex.build(res.rules, 2)               # rules reference item >= 2
    with pytest.raises(ValueError):
        RuleIndex.build(res.rules, 32, r_bucket=100)  # not a lane multiple
    empty = RuleIndex.build([], 32)                 # legal: all-padding index
    assert empty.n_rows == 0 and empty.n_rows_padded == 128
    engine = RecommendationEngine(empty, config=ServingConfig(
        k=3, data_plane="ref"))
    assert engine.recommend(Query.of([0, 1])) == []


# ---------------------------------------------------------------------------
# report invariants
# ---------------------------------------------------------------------------

def test_serving_report_invariants(mined):
    T, res = mined
    index = RuleIndex.build(res.rules, T.shape[1])
    engine = RecommendationEngine(
        index, config=ServingConfig(k=4, batch_buckets=(1, 8),
                                    data_plane="ref"))
    n = 30
    arrival = np.linspace(0.0, 100.0, n)
    _, rep = engine.serve(queries_of(T, n), arrival_s=arrival)
    assert rep.n_queries == n
    assert 0 < rep.batch_fill <= 1.0
    assert rep.p50_latency_s <= rep.p99_latency_s
    assert rep.sim_time_s > 0 and rep.qps > 0
    assert rep.energy_j > 0 and rep.switches >= 0
    assert sum(rep.bucket_counts.values()) == rep.n_batches
    assert rep.cache_hits + rep.cache_misses == n
    assert "QPS" in rep.summary()
    with pytest.raises(ValueError):
        engine.serve(queries_of(T, 3), arrival_s=[2.0, 1.0, 3.0])


# ---------------------------------------------------------------------------
# satellite: rule ordering is a reproducible total order
# ---------------------------------------------------------------------------

def test_generate_rules_order_independent_of_supports_insertion():
    T = generate_baskets(BasketConfig(n_tx=300, n_items=16, n_patterns=3,
                                      pattern_len=3, pattern_prob=0.6,
                                      seed=2))
    res = apriori(T, min_support=15)
    rules = generate_rules(res, min_confidence=0.3)
    # same supports, reversed dict insertion order -> identical rule list
    import dataclasses
    rev = dataclasses.replace(
        res, supports=dict(reversed(list(res.supports.items()))))
    assert generate_rules(rev, min_confidence=0.3) == rules
    # the sort key is a total order over the rule tuple itself
    keys = [(-r.confidence, -r.support, -r.lift, r.antecedent, r.consequent)
            for r in rules]
    assert keys == sorted(keys)
    assert len(set(keys)) == len(keys)
