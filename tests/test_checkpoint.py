"""Checkpoint store: bit-exact roundtrip, LATEST pointer, elastic re-shard."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.checkpoint.elastic import plan_resize
from repro.configs.base import get_config
from repro.core.hetero import HeterogeneityProfile
from repro.models import transformer as T
from repro.optim.adamw import init_opt_state


def small_state():
    cfg = get_config("granite-3-8b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(7))
    return cfg, (params, init_opt_state(params))


def test_roundtrip_bit_exact(tmp_path):
    cfg, state = small_state()
    store.save(str(tmp_path), 3, state, extra={"step": 3})
    restored, extra = store.restore(str(tmp_path), state)
    assert extra["step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_pointer_tracks_newest(tmp_path):
    cfg, state = small_state()
    store.save(str(tmp_path), 1, state)
    store.save(str(tmp_path), 5, state)
    assert store.latest_step(str(tmp_path)) == 5
    restored, _ = store.restore(str(tmp_path), state)   # no error


def test_restore_specific_step(tmp_path):
    cfg, (params, opt) = small_state()
    bumped = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, params)
    store.save(str(tmp_path), 1, params)
    store.save(str(tmp_path), 2, bumped)
    r1, _ = store.restore(str(tmp_path), params, step=1)
    leaves1 = jax.tree_util.tree_leaves(r1)
    orig = jax.tree_util.tree_leaves(params)
    np.testing.assert_array_equal(np.asarray(leaves1[0], np.float32),
                                  np.asarray(orig[0], np.float32))


@pytest.mark.skipif(not store.HAVE_ZSTD, reason="zstandard not installed")
def test_zstd_codec_roundtrip(tmp_path):
    cfg, state = small_state()
    store.save(str(tmp_path), 1, state, codec="zstd")
    restored, _ = store.restore(str(tmp_path), state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_raw_codec_roundtrip(tmp_path):
    """The fallback codec must work regardless of zstandard availability."""
    cfg, state = small_state()
    store.save(str(tmp_path), 2, state, codec="raw")
    assert os.path.exists(os.path.join(str(tmp_path), "step_000000002",
                                       "arrays.msgpack"))
    restored, _ = store.restore(str(tmp_path), state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_shape_mismatch_raises(tmp_path):
    cfg, (params, opt) = small_state()
    store.save(str(tmp_path), 1, params)
    wrong = jax.tree.map(
        lambda x: jnp.zeros((x.shape[0] + 1,) + x.shape[1:], x.dtype)
        if x.ndim else x, params)
    with pytest.raises(AssertionError):
        store.restore(str(tmp_path), wrong)


def _abstract_mesh(shape, names):
    # jax >= 0.5 takes (shape, names); 0.4.x takes ((name, size), ...) pairs
    try:
        return jax.sharding.AbstractMesh(shape, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


def test_resize_plan_gates_chips_and_replans():
    # AbstractMesh: plan_resize only needs shapes/axis names (no devices)
    old = _abstract_mesh((2, 4), ("data", "model"))
    new = _abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = plan_resize(old, new, global_batch=16, microbatch=2,
                       profile=HeterogeneityProfile.paper())
    assert plan.batch_plan.step_batches == 8
    assert plan.gated_chips == 0
    # shrink case
    plan2 = plan_resize(new, old, global_batch=16, microbatch=2)
    assert plan2.batch_plan is not None
    assert plan2.is_shrink or plan2.gated_chips == 0
