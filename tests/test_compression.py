"""Gradient compression: top-k + error feedback convergence, int8 quant."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.compression import (compression_ratio, dequantize_int8,
                                     ef_compress, init_error_state,
                                     quantize_int8, topk_sparsify)


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.01, 3.0, -0.2])
    out = np.asarray(topk_sparsify(g, 0.4))
    assert out[1] == -5.0 and out[3] == 3.0
    assert out[0] == 0 and out[2] == 0 and out[4] == 0


def test_error_feedback_preserves_mass():
    """compressed + error == original (nothing lost, only delayed)."""
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal(100), jnp.float32)}
    e = init_error_state(g)
    comp, e2 = ef_compress(g, e, k_frac=0.1)
    np.testing.assert_allclose(
        np.asarray(comp["a"]) + np.asarray(e2["a"]), np.asarray(g["a"]),
        atol=1e-6)


def test_ef_sgd_converges_on_quadratic():
    """min ||x - t||²; EF-compressed SGD must still converge.  The delayed
    error means the effective per-coordinate step is ~lr/k_frac, so the
    stable lr shrinks by the compression factor."""
    t = jnp.asarray(np.random.default_rng(1).standard_normal(50), jnp.float32)
    x = jnp.zeros(50)
    err = {"x": jnp.zeros(50)}
    lr = 0.04
    for _ in range(800):
        g = {"x": 2 * (x - t)}
        comp, err = ef_compress(g, err, k_frac=0.1)
        x = x - lr * comp["x"]
    assert float(jnp.linalg.norm(x - t)) < 5e-2


def test_int8_quant_roundtrip_error_bounded():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    max_err = float(jnp.abs(back - g).max())
    assert max_err <= float(s) * 0.5 + 1e-6


def test_compression_ratio_math():
    assert compression_ratio(0.01) == pytest.approx(0.02)
