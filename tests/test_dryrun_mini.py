"""Mini-mesh dry-run integration: lower+compile on 8 placeholder devices.

Runs in a SUBPROCESS because the device-count XLA flag must be set before
jax initializes, and the rest of the suite should keep seeing 1 device
(task spec: do not set the flag globally)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_test_mesh

small = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
             d_ff=128, vocab_size=512)
out = {}
for mp in (False, True):
    mesh = make_test_mesh(multi_pod=mp)
    for arch, shape in [("granite-3-8b", "train_4k"),
                        ("rwkv6-7b", "decode_32k"),
                        ("gemma3-1b", "prefill_32k")]:
        over = dict(small)
        if arch == "gemma3-1b":
            over.update(n_kv_heads=1, local_window=16, global_every=2)
        rec = lower_cell(arch, shape, mesh, profile="tuned", overrides=over,
                         opt_overrides={"grad_accum": 2})
        key = f"{arch}|{shape}|{'mp' if mp else 'pod'}"
        out[key] = {"ok": rec.get("ok", False),
                    "coll": rec["collectives"]["total_bytes"],
                    "flops": rec["cost"]["flops"]}
print("RESULT" + json.dumps(out))
'''


@pytest.fixture(scope="module")
def mini_dryrun_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_all_mini_cells_compile(mini_dryrun_results):
    assert len(mini_dryrun_results) == 6
    for key, rec in mini_dryrun_results.items():
        assert rec["ok"], key


def test_train_cell_has_collectives(mini_dryrun_results):
    rec = mini_dryrun_results["granite-3-8b|train_4k|pod"]
    assert rec["coll"] > 0          # TP all-reduces must appear
    assert rec["flops"] > 0


def test_multipod_grad_sync_spans_pods(mini_dryrun_results):
    """Multi-pod train compile succeeds and moves bytes over collectives
    (the pod axis shards the batch -> grad sync crosses pods)."""
    rec = mini_dryrun_results["granite-3-8b|train_4k|mp"]
    assert rec["ok"] and rec["coll"] > 0
