"""Data pipeline: determinism under restart, hetero-aware batch planning."""
import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets, pad_items, pad_rows
from repro.data.sharding import plan_batches, replan
from repro.data.tokens import TokenPipeline, TokenPipelineConfig


def test_token_pipeline_deterministic():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=5)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 3, 17):
        np.testing.assert_array_equal(p1.batch(step)["tokens"],
                                      p2.batch(step)["tokens"])


def test_token_pipeline_steps_differ():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=64, global_batch=8)
    p = TokenPipeline(cfg)
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_token_pipeline_restart_equivalence():
    """Resuming at step k yields the same stream a continuous run saw."""
    cfg = TokenPipelineConfig(vocab_size=512, seq_len=32, global_batch=4)
    run1 = [TokenPipeline(cfg).batch(s)["tokens"] for s in range(6)]
    fresh = TokenPipeline(cfg)                      # "restarted" job
    run2 = [fresh.batch(s)["tokens"] for s in range(3, 6)]
    for a, b in zip(run1[3:], run2):
        np.testing.assert_array_equal(a, b)


def test_baskets_reproducible_and_padded():
    cfg = BasketConfig(n_tx=100, n_items=50, seed=3)
    T1, T2 = generate_baskets(cfg), generate_baskets(cfg)
    np.testing.assert_array_equal(T1, T2)
    P = pad_items(T1)
    assert P.shape[1] % 128 == 0
    assert (P[:, 50:] == 0).all()
    R = pad_rows(T1)
    assert R.shape[0] % 8 == 0


def test_plan_batches_proportional_and_exact():
    prof = HeterogeneityProfile.paper()
    plan = plan_batches(prof, global_batch=80, microbatch=1)
    assert plan.counts.sum() == 80
    # 400-speed core gets ~5x the 80-speed core
    assert plan.counts[3] >= 4 * plan.counts[0]


def test_replan_after_observation():
    prof = HeterogeneityProfile.homogeneous(4, 10.0)
    plan = plan_batches(prof, 64, 1)
    assert plan.counts.tolist() == [16, 16, 16, 16]
    prof.observe(0, work_done=1.0, seconds=1.0)   # device 0 now much slower
    plan2 = replan(prof, plan)
    assert plan2.counts[0] < 16
    assert plan2.counts.sum() == 64


def test_plan_batches_rejects_indivisible():
    with pytest.raises(ValueError):
        plan_batches(HeterogeneityProfile.homogeneous(2), 10, 3)
