"""The autotune subsystem's contracts:

* cache round-trip is deterministic (same entries, byte-identical re-save);
* the sweep verifies every candidate bit-identical to the oracle and picks
  the argmin of the *measured* costs;
* a cold/corrupt cache degrades to the roofline-seeded defaults without
  ever raising — autotuning may only make things faster, never break them;
* ``CostModelPolicy.from_autotune`` turns measured walls into effective
  peak/bandwidth, the planes' plans actually change versus the datasheet
  constants on a heterogeneous profile, and every ``PhaseRecord`` says
  where its planning costs came from (``cost_source``).
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.scheduler import TaskSpec
from repro.kernels.autotune.cache import (AutotuneCache, default_cache,
                                          resolve_config, shape_bucket)
from repro.kernels.autotune.tuner import standard_shapes, tune, tune_into
from repro.kernels.support_count.ops import support_count
from repro.kernels.support_count.ref import support_count_ref
from repro.launch.tuning import (TUNABLE_KERNELS, default_config,
                                 kernel_candidates, shape_flops_bytes)
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.runtime import (CostModelPolicy, MeasuredPhase, Runtime,
                           autotuned_costmodel)

SC_SMOKE = (64, 128, 128)       # 2 candidates at this shape: one per variant


# ---------------------------------------------------------------------------
# cache round-trip + lookup
# ---------------------------------------------------------------------------

def test_cache_roundtrip_deterministic(tmp_path):
    cache = AutotuneCache()
    cfg = {"variant": "packed", "bn": 64, "bm": 128}
    cache.put("support_count", SC_SMOKE, cfg, 123.456,
              swept=[{"config": cfg, "cost_us": 123.456, "matched": True}],
              device="cpu")
    cache.put("rule_match", (8, 128, 128),
              {"variant": "mxu", "bb": 8, "br": 128, "bi": 128}, 55.5,
              device="cpu")
    path = str(tmp_path / "cache.json")
    cache.save(path)
    loaded = AutotuneCache.load(path)
    assert loaded.load_error is None
    assert loaded.entries == cache.entries
    loaded.save(str(tmp_path / "resave.json"))
    with open(path) as a, open(tmp_path / "resave.json") as b:
        assert a.read() == b.read()         # byte-identical re-save


def test_lookup_exact_then_nearest_bucket():
    cache = AutotuneCache()
    cfg = {"variant": "packed", "bn": 64, "bm": 128}
    cache.put("support_count", SC_SMOKE, cfg, 10.0, device="cpu")
    # exact bucket, and a different shape rounding into the same bucket
    assert cache.lookup("support_count", SC_SMOKE, "cpu")["config"] == cfg
    assert shape_bucket("support_count", (50, 100, 100)) \
        == shape_bucket("support_count", SC_SMOKE)
    assert cache.lookup("support_count", (50, 100, 100), "cpu")["config"] \
        == cfg
    # far-away shape: nearest-bucket fallback still serves the one entry
    assert cache.lookup("support_count", (4096, 8192, 256), "cpu")["config"] \
        == cfg
    # but never across device kinds or kernels
    assert cache.lookup("support_count", SC_SMOKE, "tpu_v99") is None
    assert cache.lookup("rule_match", (8, 128, 128), "cpu") is None


def test_checked_in_cache_covers_both_kernels():
    cache = default_cache(reload=True)
    assert cache.load_error is None
    for kernel in TUNABLE_KERNELS:
        entries = cache.entries_for(kernel, "cpu")
        assert entries, f"checked-in cache has no cpu entries for {kernel}"
        for ent in entries:
            assert ent["cost_us"] > 0 and ent["source"] == "measured"
            assert "variant" in ent["config"]


# ---------------------------------------------------------------------------
# degradation: cold / corrupt caches fall back to roofline defaults
# ---------------------------------------------------------------------------

def test_cold_and_corrupt_cache_degrade(tmp_path):
    missing = AutotuneCache.load(str(tmp_path / "absent.json"))
    assert missing.load_error is not None and len(missing) == 0

    bad = tmp_path / "bad.json"
    bad.write_text("{this is not json")
    corrupt = AutotuneCache.load(str(bad))
    assert corrupt.load_error is not None and "corrupt" in corrupt.load_error
    assert len(corrupt) == 0

    schema = tmp_path / "schema.json"
    schema.write_text(json.dumps({"entries": {"k": {"shape": [1, 2, 3]}}}))
    assert AutotuneCache.load(str(schema)).load_error is not None

    # the resolver degrades to the roofline-seeded default, never raises
    want = default_config("support_count", SC_SMOKE)
    assert resolve_config("support_count", SC_SMOKE, corrupt) == want
    assert resolve_config("support_count", SC_SMOKE, False) == want
    pin = {"variant": "mxu", "bn": 8, "bm": 128, "bi": 128}
    got = resolve_config("support_count", SC_SMOKE, pin)
    assert got == pin and got is not pin     # pinned dicts pass through, copied

    # and the kernel itself still runs (correctly) off a cold cache
    rng = np.random.default_rng(3)
    T = (rng.random((32, 64)) < 0.3).astype(np.uint8)
    C = (rng.random((8, 64)) < 0.1).astype(np.uint8)
    np.testing.assert_array_equal(
        np.asarray(support_count(jnp.asarray(T), jnp.asarray(C),
                                 tuning=corrupt)),
        np.asarray(support_count_ref(jnp.asarray(T), jnp.asarray(C))))


def test_autotuned_costmodel_degrades_to_roofline():
    pol = autotuned_costmodel("support_count", cache=AutotuneCache())
    assert isinstance(pol, CostModelPolicy)
    assert pol.cost_source == "roofline"     # constants, not measurements
    with pytest.raises(ValueError):
        CostModelPolicy.from_autotune(AutotuneCache(), "support_count",
                                      device="cpu")


# ---------------------------------------------------------------------------
# the sweep: bit-identical configs only, argmin of measured cost
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel,shape", [("support_count", SC_SMOKE),
                                          ("rule_match", (8, 128, 128))])
def test_sweep_configs_all_match_oracle(kernel, shape):
    res = tune(kernel, shape, reps=3)
    assert res.swept
    assert all(s.matched for s in res.swept), \
        [s.config for s in res.swept if not s.matched]
    best = min((s for s in res.swept if s.matched), key=lambda s: s.cost_us)
    assert res.best == best.config and res.cost_us == best.cost_us
    variants = {s.config["variant"] for s in res.swept}
    assert variants == {"mxu", "packed"}     # both implementations swept


def test_tune_picks_argmin_of_measured_cost():
    """Scripted timer: the sweep must pick whichever config *measures*
    cheapest, not the roofline favourite (candidate order)."""
    cands = kernel_candidates("support_count", SC_SMOKE)
    assert len(cands) == 2
    walls = [10.0, 1.0]                      # seconds per rep, per config
    ticks = []
    for ci, wall in enumerate(walls):        # 3 reps x 2 timer calls each
        t = 1e6 * ci
        for _ in range(3):
            ticks.extend([t, t + wall])
            t += wall
    it = iter(ticks)
    res = tune("support_count", SC_SMOKE, configs=cands, reps=3,
               timer=lambda: next(it))
    assert res.best == cands[1]
    assert res.cost_us == pytest.approx(1.0e6)       # 1 s in us
    assert [s.cost_us for s in res.swept] \
        == [pytest.approx(10.0e6), pytest.approx(1.0e6)]


def test_tune_into_writes_audited_entries():
    cache = AutotuneCache()
    results = tune_into(cache, "support_count", shapes=[SC_SMOKE], reps=3)
    assert len(results) == 1 and len(cache) == 1
    ent = cache.lookup("support_count", SC_SMOKE)
    assert ent["config"] == results[0].best
    assert ent["source"] == "measured" and ent["shape"] == list(SC_SMOKE)
    assert all(s["matched"] for s in ent["swept"])   # full sweep audited
    # the ops resolver serves this cache's winner when handed the cache
    assert resolve_config("support_count", SC_SMOKE, cache) == ent["config"]


def test_standard_shapes_smoke_is_tiny():
    for kernel in TUNABLE_KERNELS:
        full = standard_shapes(kernel)
        assert len(standard_shapes(kernel, smoke=True)) == 1
        assert len(full) > 1
        assert len({shape_bucket(kernel, s) for s in full}) == len(full)


# ---------------------------------------------------------------------------
# the feedback loop: measured costs reach the scheduler + the ledger
# ---------------------------------------------------------------------------

def _measured_cache(wall_us=1e6):
    cache = AutotuneCache()
    cache.put("support_count", (1024, 2048, 128),
              {"variant": "packed", "bn": 512, "bm": 256}, wall_us,
              device="cpu")
    return cache


def test_from_autotune_seeds_effective_rates():
    wall_us = 4000.0
    pol = CostModelPolicy.from_autotune(_measured_cache(wall_us),
                                        "support_count", device="cpu")
    flops, bytes_ = shape_flops_bytes("support_count", (1024, 2048, 128))
    assert pol.cost_source == "autotune"
    assert pol.peak_flops == pytest.approx(flops / (wall_us * 1e-6))
    assert pol.hbm_bw == pytest.approx(bytes_ / (wall_us * 1e-6))
    assert pol.flops_per_byte == pytest.approx(flops / bytes_)


def test_autotune_fed_costs_change_the_plan():
    """Same tiles, same byte estimates: the autotune-seeded policy must
    produce a different cost distribution — and a different LPT plan on
    the paper's heterogeneous profile — than the datasheet constants."""
    profile = HeterogeneityProfile.paper()
    const = CostModelPolicy()
    tuned = CostModelPolicy.from_autotune(_measured_cache(), "support_count",
                                          device="cpu")
    # effective (measured) ridge point differs from the datasheet's, so an
    # intensity between the two is flop-bound under exactly one model
    ridge_c = const.peak_flops / const.hbm_bw
    ridge_t = tuned.peak_flops / tuned.hbm_bw
    assert ridge_c != pytest.approx(ridge_t)
    mid = float(np.sqrt(ridge_c * ridge_t))
    tile_bytes = np.array([1e6, 0.9e6, 0.8e6, 0.7e6])
    tile_flops = np.array([mid * 1e6, 0.0, 0.0, 0.0])
    task = TaskSpec("count_tiles", cost=float(tile_bytes.sum()), n_tiles=4)

    plans = {}
    for name, pol in (("const", const), ("tuned", tuned)):
        rt = Runtime(profile, policy=pol)
        costs = pol.tile_costs(rt, task, tile_bytes, tile_flops)
        assert costs.sum() == pytest.approx(tile_bytes.sum())  # renormalized
        asg, _, _ = pol.plan(rt, task, costs)
        plans[name] = (costs, asg.tiles_of)
    rel_c = plans["const"][0] / plans["const"][0].sum()
    rel_t = plans["tuned"][0] / plans["tuned"][0].sum()
    assert not np.allclose(rel_c, rel_t)
    assert plans["const"][1] != plans["tuned"][1]


def test_phase_records_note_cost_source():
    profile = HeterogeneityProfile.paper()
    task = TaskSpec("count_tiles", cost=4.0, n_tiles=4)
    execute = lambda asg, costs: MeasuredPhase(result="ok")  # noqa: E731
    for policy, want in (("static", "bytes"), ("dynamic", "bytes"),
                         ("costmodel", "roofline")):
        rt = Runtime(profile, policy=policy)
        _, rec = rt.run_phase(task, execute)
        assert rec.cost_source == want, policy
    rt = Runtime(profile, policy=CostModelPolicy.from_autotune(
        _measured_cache(), "support_count", device="cpu"))
    _, rec = rt.run_phase(task, execute)
    assert rec.cost_source == "autotune"
    _, ser = rt.run_serial("load", 1.0)      # serial phases stamped too
    assert ser.cost_source == "autotune"


def test_pipeline_costmodel_policy_is_autotune_fed():
    """policy="costmodel" + autotune on (the default) seeds planning from
    the checked-in cache; --no-autotune pins the datasheet constants."""
    profile = HeterogeneityProfile.paper()
    on = MarketBasketPipeline(profile, PipelineConfig(policy="costmodel"))
    assert on.runtime.policy.cost_source == "autotune"
    off = MarketBasketPipeline(
        profile, PipelineConfig(policy="costmodel", autotune=False))
    assert off.runtime.policy.cost_source == "roofline"
