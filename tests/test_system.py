"""End-to-end behaviour tests for the paper's system: the full mining job,
training with checkpoint/restart + fault injection, serving."""
import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori_bruteforce
from repro.data.baskets import BasketConfig, generate_baskets, pad_items
from repro.distributed.fault import FaultEvent, FaultPlan


def test_end_to_end_mining_matches_oracle():
    from repro.launch.mine import mine
    result = mine(n_tx=600, n_items=48, min_support=0.05,
                  min_confidence=0.5, profile_name="paper",
                  split="lpt", n_tiles=8, top=0)
    T = pad_items(generate_baskets(BasketConfig(n_tx=600, n_items=48, seed=0)))
    want = apriori_bruteforce(T, max(1, int(0.05 * 600)), max_k=8)
    assert result.supports == want
    assert all(r.confidence >= 0.5 for r in result.rules)


def test_mining_lpt_beats_equal_split_makespan():
    from repro.launch.mine import mine
    r_lpt = mine(n_tx=512, n_items=32, min_support=0.05,
                 min_confidence=0.6, split="lpt", n_tiles=16, top=0)
    r_eq = mine(n_tx=512, n_items=32, min_support=0.05,
                min_confidence=0.6, split="equal", n_tiles=16, top=0)
    assert r_lpt.report.total_time_s < r_eq.report.total_time_s
    assert r_lpt.supports == r_eq.supports     # schedule never changes results


def test_training_loss_decreases():
    from repro.launch.train import train
    hist = train("gemma3-1b", steps=30, smoke=True, batch=8, seq=64,
                 lr=3e-3, log_every=100)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first - 0.1, (first, last)


def test_training_checkpoint_restart_identical(tmp_path):
    """Kill a 20-step run at its step-10 checkpoint and resume: the resumed
    half must reproduce the original run exactly (deterministic pipeline +
    bit-exact checkpoint + identical LR schedule)."""
    import os
    import shutil
    from repro.launch.train import train
    d1 = str(tmp_path / "ck")
    h1 = train("granite-3-8b", steps=20, smoke=True, batch=4, seq=32,
               lr=1e-3, ckpt_dir=d1, ckpt_every=10, log_every=100)
    # simulate the failure: only the step-10 checkpoint survives
    shutil.rmtree(os.path.join(d1, "step_000000020"))
    with open(os.path.join(d1, "LATEST"), "w") as f:
        f.write("step_000000010")
    h2 = train("granite-3-8b", steps=20, smoke=True, batch=4, seq=32,
               lr=1e-3, ckpt_dir=d1, ckpt_every=50, restore=True, log_every=100)
    np.testing.assert_allclose(h1["loss"][10:], h2["loss"], rtol=1e-4)


def test_training_with_straggler_replans():
    from repro.launch.train import train
    fp = FaultPlan([FaultEvent(step=5, kind="straggler", device=0, severity=4.0)])
    prof = HeterogeneityProfile.homogeneous(4)
    hist = train("hymba-1.5b", steps=10, smoke=True, batch=8, seq=32,
                 fault_plan=fp, profile=prof, log_every=100)
    assert hist["replans"] >= 1
    assert np.isfinite(hist["loss"]).all()


def test_training_with_device_loss_elastic():
    from repro.launch.train import train
    fp = FaultPlan([FaultEvent(step=3, kind="device_loss", device=1)])
    prof = HeterogeneityProfile.homogeneous(4)
    hist = train("rwkv6-7b", steps=8, smoke=True, batch=8, seq=32,
                 fault_plan=fp, profile=prof, log_every=100)
    assert hist["replans"] >= 1


def test_serving_produces_tokens():
    from repro.launch.serve import serve_demo
    out = serve_demo("gemma3-1b", batch=2, prompt_len=8, new_tokens=8)
    assert out["tokens"].shape == (2, 8)
    assert (out["tokens"] >= 0).all()


def test_serving_greedy_deterministic():
    from repro.launch.serve import serve_demo
    o1 = serve_demo("granite-3-8b", batch=2, prompt_len=8, new_tokens=6)
    o2 = serve_demo("granite-3-8b", batch=2, prompt_len=8, new_tokens=6)
    np.testing.assert_array_equal(o1["tokens"], o2["tokens"])
