"""End-to-end behaviour tests for the paper's system: the full mining job,
training with checkpoint/restart + fault injection, serving."""
import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori_bruteforce
from repro.data.baskets import BasketConfig, generate_baskets, pad_items
from repro.distributed.fault import FaultEvent, FaultPlan


def test_end_to_end_mining_matches_oracle():
    from repro.launch.mine import mine
    result = mine(n_tx=600, n_items=48, min_support=0.05,
                  min_confidence=0.5, profile_name="paper",
                  split="lpt", n_tiles=8, top=0)
    T = pad_items(generate_baskets(BasketConfig(n_tx=600, n_items=48, seed=0)))
    want = apriori_bruteforce(T, max(1, int(0.05 * 600)), max_k=8)
    assert result.supports == want
    assert all(r.confidence >= 0.5 for r in result.rules)


def test_mining_lpt_beats_equal_split_makespan():
    from repro.launch.mine import mine
    r_lpt = mine(n_tx=512, n_items=32, min_support=0.05,
                 min_confidence=0.6, split="lpt", n_tiles=16, top=0)
    r_eq = mine(n_tx=512, n_items=32, min_support=0.05,
                min_confidence=0.6, split="equal", n_tiles=16, top=0)
    assert r_lpt.report.total_time_s < r_eq.report.total_time_s
    assert r_lpt.supports == r_eq.supports     # schedule never changes results


def test_training_loss_decreases():
    from repro.launch.train import train
    hist = train("gemma3-1b", steps=30, smoke=True, batch=8, seq=64,
                 lr=3e-3, log_every=100)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first - 0.1, (first, last)


def test_training_checkpoint_restart_identical(tmp_path):
    """Kill a 20-step run at its step-10 checkpoint and resume: the resumed
    half must reproduce the original run exactly (deterministic pipeline +
    bit-exact checkpoint + identical LR schedule)."""
    import os
    import shutil
    from repro.launch.train import train
    d1 = str(tmp_path / "ck")
    h1 = train("granite-3-8b", steps=20, smoke=True, batch=4, seq=32,
               lr=1e-3, ckpt_dir=d1, ckpt_every=10, log_every=100)
    # simulate the failure: only the step-10 checkpoint survives
    shutil.rmtree(os.path.join(d1, "step_000000020"))
    with open(os.path.join(d1, "LATEST"), "w") as f:
        f.write("step_000000010")
    h2 = train("granite-3-8b", steps=20, smoke=True, batch=4, seq=32,
               lr=1e-3, ckpt_dir=d1, ckpt_every=50, restore=True, log_every=100)
    np.testing.assert_allclose(h1["loss"][10:], h2["loss"], rtol=1e-4)


def test_training_with_straggler_replans():
    from repro.launch.train import train
    fp = FaultPlan([FaultEvent(step=5, kind="straggler", device=0, severity=4.0)])
    prof = HeterogeneityProfile.homogeneous(4)
    hist = train("hymba-1.5b", steps=10, smoke=True, batch=8, seq=32,
                 fault_plan=fp, profile=prof, log_every=100)
    assert hist["replans"] >= 1
    assert np.isfinite(hist["loss"]).all()


def test_training_with_device_loss_elastic():
    from repro.launch.train import train
    fp = FaultPlan([FaultEvent(step=3, kind="device_loss", device=1)])
    prof = HeterogeneityProfile.homogeneous(4)
    hist = train("rwkv6-7b", steps=8, smoke=True, batch=8, seq=32,
                 fault_plan=fp, profile=prof, log_every=100)
    assert hist["replans"] >= 1


def test_serving_produces_tokens():
    from repro.launch.serve import serve_demo
    out = serve_demo("gemma3-1b", batch=2, prompt_len=8, new_tokens=8)
    assert out["tokens"].shape == (2, 8)
    assert (out["tokens"] >= 0).all()


def test_serving_greedy_deterministic():
    from repro.launch.serve import serve_demo
    o1 = serve_demo("granite-3-8b", batch=2, prompt_len=8, new_tokens=6)
    o2 = serve_demo("granite-3-8b", batch=2, prompt_len=8, new_tokens=6)
    np.testing.assert_array_equal(o1["tokens"], o2["tokens"])


# ---------------------------------------------------------------------------
# cross-plane closed loop: stream -> index refresh -> live serving
# ---------------------------------------------------------------------------

def test_stream_refresh_serve_closed_loop_dynamic():
    """All planes as one system under policy=dynamic: micro-batches mined
    incrementally, rules hot-swapped into the live engine, queries served
    from the freshest index — with version monotonicity, no stale reads
    across refresh(), and the shared-ledger accounting invariants."""
    from repro.data.baskets import stationary_baskets
    from repro.serving import (RecommendationEngine, RuleIndex,
                               ServingConfig, recommend_bruteforce)
    from repro.streaming import (StreamingConfig, StreamingMiner,
                                 TransactionStream)
    n_items = 32
    # phase 1 and phase 2 of the stream carry different pattern sets, so
    # the rule set genuinely changes mid-run and refresh() must re-serve
    T = np.vstack([stationary_baskets(512, n_items, n_patterns=4, seed=1),
                   stationary_baskets(512, n_items, n_patterns=4, seed=2)])
    cfg = StreamingConfig(window=256, batch_size=64, min_support=0.15,
                          min_confidence=0.5, n_tiles=4, data_plane="ref",
                          policy="dynamic")
    engine = RecommendationEngine(
        RuleIndex.build([], n_items),
        config=ServingConfig(k=3, data_plane="ref", policy="dynamic",
                             cache_size=256))
    miner = StreamingMiner(n_items, config=cfg, engine=engine)

    from repro.serving import Query
    query = Query.of(list(range(6)))        # covers items of several rules
    versions, serve_reports = [], []
    for batch in TransactionStream(T, cfg.batch_size):
        miner.process_batch(batch)
        versions.append(engine.index.version)
        got, srep = engine.serve([query])
        serve_reports.append(srep)
        # no stale read: what we got is exactly what the *current* rules
        # imply — a cache entry surviving a refresh would violate this
        assert got[0] == recommend_bruteforce(miner.rules, query.payload, 3)
        # serving the same query twice without a refresh must hit the LRU:
        # no miss, hence no scoring map phase (admission still runs)
        _, srep2 = engine.serve([query])
        assert srep2.cache_hits == 1 and srep2.cache_misses == 0
        assert not srep2.ledger.by_kind("map")

    # RuleIndex.version is monotone and actually advanced mid-run
    assert versions == sorted(versions)
    assert versions[-1] > versions[0] >= 0
    assert engine.index.version == miner.index.version

    # ledger invariants, streaming plane: every phase emitted exactly one
    # PhaseRecord, and the report totals ARE the ledger slice totals
    sreport = miner.take_report()
    assert sreport.n_revalidations >= 1     # the distribution flip forced it
    assert sum(b.n_phases for b in sreport.batches) == \
        sreport.ledger.n_phases
    assert sreport.total_time_s == pytest.approx(
        sum(p.sim_time_s for p in sreport.ledger.phases))
    assert sreport.total_energy_j == pytest.approx(
        sum(p.energy_j for p in sreport.ledger.phases))
    assert sreport.total_switches == \
        sum(p.switches for p in sreport.ledger.phases)
    assert {p.kind for p in sreport.ledger.phases} <= {"serial", "map"}
    assert all(p.policy == "dynamic" for p in sreport.ledger.phases)

    # ledger invariants, serving plane: each serve() call owns its slice
    for srep in serve_reports:
        assert srep.ledger is not None
        assert srep.energy_j == pytest.approx(srep.ledger.total_energy_j)
        assert srep.switches == srep.ledger.total_switches
        # one serial admission record per batch, plus map scoring records
        assert len(srep.ledger.by_kind("serial")) == srep.n_batches
    # nothing leaked into the live runtimes
    assert miner.runtime.ledger.n_phases == 0
    assert engine.runtime.ledger.n_phases == 0


# ---------------------------------------------------------------------------
# constraint surfacing end to end (regression: was only unit-tested)
# ---------------------------------------------------------------------------

def test_min_speed_violation_reaches_pipeline_report():
    """A serial min_speed no core satisfies must flow from assign_serial
    through every PhaseRecord into the PipelineReport summary."""
    from repro.data.baskets import BasketConfig, generate_baskets
    from repro.pipeline import MarketBasketPipeline, PipelineConfig
    T = generate_baskets(BasketConfig(n_tx=300, n_items=24, seed=5))
    res = MarketBasketPipeline(config=PipelineConfig(
        min_support=0.05, n_tiles=4,
        serial_min_speed=1e6)).run(T)       # paper cores top out at 400
    rep = res.report
    assert rep.constraint_violations >= 2   # candgen rounds + rules phase
    serial = [p for p in rep.ledger.phases if p.kind == "serial"]
    assert serial and all(p.constraint_violated for p in serial)
    assert "WARNING" in rep.summary() and "min_speed" in rep.summary()
    # the satisfiable case stays clean
    ok = MarketBasketPipeline(config=PipelineConfig(
        min_support=0.05, n_tiles=4, serial_min_speed=100.0)).run(T)
    assert ok.report.constraint_violations == 0
    assert "WARNING" not in ok.report.summary()
    assert ok.supports == res.supports      # a flag, never a result change


def test_min_speed_violation_reaches_serving_report():
    from repro.data.baskets import BasketConfig, generate_baskets
    from repro.pipeline import MarketBasketPipeline, PipelineConfig
    from repro.serving import (Query, RecommendationEngine, RuleIndex,
                               ServingConfig)
    T = generate_baskets(BasketConfig(n_tx=400, n_items=24, seed=2))
    res = MarketBasketPipeline(config=PipelineConfig(
        min_support=0.05, min_confidence=0.5, n_tiles=4)).run(T)
    index = RuleIndex.build(res.rules, 24)
    engine = RecommendationEngine(
        index, config=ServingConfig(k=3, batch_buckets=(8,),
                                    data_plane="ref", cache_size=0,
                                    admission_min_speed=1e6))
    queries = [Query.of(list(np.nonzero(row)[0])) for row in T[:16]]
    _, rep = engine.serve(queries)
    assert rep.constraint_violations == rep.n_batches > 0
    assert "WARNING" in rep.summary() and "min_speed" in rep.summary()
    # same engine, satisfiable bound: clean report
    engine2 = RecommendationEngine(
        index, config=ServingConfig(k=3, batch_buckets=(8,),
                                    data_plane="ref", cache_size=0,
                                    admission_min_speed=100.0))
    _, rep2 = engine2.serve(queries)
    assert rep2.constraint_violations == 0
    assert "WARNING" not in rep2.summary()


def test_min_speed_violation_reaches_streaming_report():
    from repro.data.baskets import stationary_baskets
    from repro.streaming import (StreamingConfig, StreamingMiner,
                                 TransactionStream)
    T = stationary_baskets(512, 32, n_patterns=4, seed=3)
    cfg = StreamingConfig(window=128, batch_size=64, min_support=0.15,
                          n_tiles=2, data_plane="ref", power="none",
                          serial_min_speed=1e6)
    miner = StreamingMiner(32, config=cfg)
    report = miner.run(TransactionStream(T, cfg.batch_size))
    assert report.constraint_violations > 0
    assert "WARNING" in report.summary()
