"""Per-arch smoke tests (task spec f): reduced config, one forward/train
step on CPU, output shapes + no NaNs; decode-path consistency; perf-lever
parity (chunked attention / chunked vocab loss == naive)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs
from repro.launch import steps as S
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state

ARCHS = list_archs()


def smoke_batch(cfg, B=2, S_len=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {
            "frames": jnp.asarray(rng.standard_normal((B, S_len, cfg.d_model)),
                                  jnp.bfloat16),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S_len, cfg.n_codebooks)),
                jnp.int32),
        }
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S_len)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    loss = T.model_loss(params, cfg, batch)
    assert np.isfinite(float(loss)), arch

    step = S.make_train_step(cfg, AdamWConfig(lr=1e-3), grad_accum=1)
    opt = init_opt_state(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(new_params)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S_max = 2, 32
    cache = T.init_cache(cfg, B, S_max)
    tok = (jnp.zeros((B, 1, cfg.n_codebooks), jnp.int32)
           if cfg.frontend == "audio" else jnp.zeros((B, 1), jnp.int32))
    logits, new_cache = T.decode_step(params, cfg, cache, tok, 0)
    if cfg.frontend == "audio":
        assert logits.shape == (B, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    jax.tree.map(lambda a, b: a.shape == b.shape, cache, new_cache)


@pytest.mark.parametrize("arch", ["granite-3-8b", "rwkv6-7b", "hymba-1.5b",
                                  "gemma3-1b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits at position t == full-forward logits at t."""
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S_len = 1, 8
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_len)), jnp.int32)

    # full forward logits
    x = params["embed"][toks]
    h, _ = T.forward_hidden(params, cfg, x)
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    full_logits = np.asarray(jnp.einsum("bsd,vd->bsv", h, w), np.float32)

    cache = T.init_cache(cfg, B, S_len)
    for t in range(S_len):
        logits, cache = T.decode_step(params, cfg, cache, toks[:, t][:, None], t)
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   full_logits[:, t], atol=0.15, rtol=0.05)


def test_chunked_attention_parity():
    cfg = get_config("granite-3-8b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    l_naive = float(T.model_loss(params, cfg, batch))
    l_chunk = float(T.model_loss(
        params, cfg.replace(attention_impl="chunked", attention_chunk=8), batch))
    assert abs(l_naive - l_chunk) < 2e-3


def test_chunked_vocab_loss_parity():
    cfg = get_config("minitron-8b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    l_dense = float(T.model_loss(params, cfg, batch))
    l_chunk = float(T.model_loss(params, cfg.replace(vocab_loss_chunk=64), batch))
    assert abs(l_dense - l_chunk) < 2e-3


def test_chunked_vocab_loss_grad_parity():
    cfg = get_config("granite-3-8b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    g1 = jax.grad(lambda p: T.model_loss(p, cfg, batch))(params)
    g2 = jax.grad(lambda p: T.model_loss(
        p, cfg.replace(vocab_loss_chunk=64), batch))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


def test_sliding_window_reduces_context():
    """Gemma-style local layers must not attend beyond the window."""
    cfg = get_config("gemma3-1b", smoke=True).replace(
        n_layers=1, global_every=100, local_window=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    t1 = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    t2 = t1.at[0, 0].set((int(t1[0, 0]) + 7) % cfg.vocab_size)  # change token 0
    x1 = params["embed"][t1]
    x2 = params["embed"][t2]
    h1, _ = T.forward_hidden(params, cfg, x1)
    h2, _ = T.forward_hidden(params, cfg, x2)
    # position 15 is > window away from position 0 -> unaffected
    np.testing.assert_allclose(np.asarray(h1[0, 15], np.float32),
                               np.asarray(h2[0, 15], np.float32), atol=1e-3)
    # position 1 IS affected
    assert np.abs(np.asarray(h1[0, 1], np.float32)
                  - np.asarray(h2[0, 1], np.float32)).max() > 1e-4


def test_grad_accum_matches_single_batch():
    cfg = get_config("granite-3-8b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, B=4)
    opt = init_opt_state(params)
    s1 = S.make_train_step(cfg, AdamWConfig(lr=1e-3, clip_norm=0), grad_accum=1)
    s2 = S.make_train_step(cfg, AdamWConfig(lr=1e-3, clip_norm=0), grad_accum=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
