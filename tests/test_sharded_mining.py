"""Distributed mining plane: sharded-vs-single-device parity, run_sharded
vs SimulatedCluster parity, energy on the sharded path (priced by the
shared Runtime ledger), switching-policy independence of the mined result,
and device_loss → shard re-planning.  Device-backed checks run in a
subprocess with 8 forced host devices (like test_distributed); plan math
is tested host-side."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.core.mapreduce import (MapReduceJob, SimulatedCluster, run_sharded)
from repro.core.power import PowerModel
from repro.core.scheduler import TaskSpec
from repro.data.baskets import BasketConfig, generate_baskets
from repro.distributed.fault import FaultEvent, FaultPlan
from repro.distributed.mining import ShardedMiner, make_shard_mesh, mesh_profile
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.runtime import MeasuredPhase, Runtime

out = {}

# ---- 1. run_sharded vs SimulatedCluster: same job, same tiles, same value
n_dev = 8
profile = HeterogeneityProfile.homogeneous(n_dev, 100.0)
rng = np.random.default_rng(0)
tiles = [rng.integers(0, 16, 32).astype(np.int32) for _ in range(n_dev)]
job = MapReduceJob("wc",
    map_fn=lambda t: jnp.bincount(jnp.asarray(t), length=16),
    combine_fn=lambda a, b: a + b,
    zero_fn=lambda: jnp.zeros(16, jnp.int32))
sim, sim_rep = SimulatedCluster(profile).run(job, tiles)
mesh = make_shard_mesh(n_dev)
shard, shard_rep = run_sharded(job, jnp.concatenate([jnp.asarray(t) for t in tiles]),
                               mesh, mesh.axis_names[0], profile=profile)
out["parity_value_ok"] = bool((np.asarray(sim) == np.asarray(shard)).all())

# ---- 2. sharded energy is priced by the shared Runtime (exactly once):
# drive the same shard_map job through Runtime.run_phase with the shard
# layout as a pinned assignment, as ShardedMiner does
rt = Runtime(profile, policy="static", power=PowerModel.cpu(profile))
costs = np.full(n_dev, 32.0 * 4)                 # bytes per rank
def _exec(asg, c):
    res, rep = run_sharded(job, jnp.concatenate(
        [jnp.asarray(t) for t in tiles]), mesh, mesh.axis_names[0])
    return MeasuredPhase(result=res, wall_s=rep.makespan)
shard2, rec = rt.run_phase(
    TaskSpec("wc-runtime", float(costs.sum()), parallel=True, n_tiles=n_dev),
    _exec, tile_costs=costs, assignment=rt.pinned_assignment(costs))
out["sharded_energy_ok"] = rec.energy_j > 0
out["sharded_makespan_ok"] = (rec.sim_time_s > 0
                              and bool((np.asarray(sim)
                                        == np.asarray(shard2)).all()))

# ---- 3. sharded miner == single-device pipeline, bit for bit
T = generate_baskets(BasketConfig(n_tx=1024, n_items=48, seed=7))
cfg = PipelineConfig(min_support=0.05, min_confidence=0.6)
single = MarketBasketPipeline(config=cfg).run(T)
miner = ShardedMiner(config=cfg, verify_rounds=True)
sharded = miner.run(T)
out["mining_supports_ok"] = sharded.supports == single.supports
out["mining_rules_ok"] = sharded.rules == single.rules
rep = sharded.report
out["mining_report_ok"] = (rep.execution == "sharded" and rep.n_shards == 8
                           and sum(rep.shard_rows) >= 1024
                           and rep.tiles_invariant_ok()
                           and rep.total_energy_j > 0)

# ---- 4. device_loss mid-mine -> re-plan, same answer, moves surfaced
miner2 = ShardedMiner(config=cfg, verify_rounds=True)
faulted = miner2.run(T, faults=FaultPlan([FaultEvent(2, "device_loss", 3)]))
frep = faulted.report
out["replan_result_ok"] = faulted.supports == single.supports
r2 = [r for r in frep.rounds if r.k == 2][0]
out["replan_counts_ok"] = (frep.replans == 1
                           and frep.shard_rows[3] == 0
                           and r2.reissued > 0
                           and r2.failed_devices == [3]
                           and frep.total_reissued > 0)
# the dead rank holds no real rows afterwards -> gated (zero busy seconds)
later = [r for r in frep.rounds if r.k >= 2 and r.n_tiles]
out["replan_gating_ok"] = all(r.map_busy_s[3] == 0.0 for r in later)

# ---- 5. heterogeneous split: fastest rank owns the most rows
prof = mesh_profile(8)      # cycled 80/120/200/400
miner3 = ShardedMiner(profile=prof, config=cfg)
res3 = miner3.run(T)
rows = np.asarray(res3.report.shard_rows, dtype=float)
out["hetero_split_ok"] = bool(
    res3.supports == single.supports
    and rows[np.argmax(prof.speeds)] == rows.max()
    and rows[np.argmax(prof.speeds)] > rows[np.argmin(prof.speeds)])

# ---- 6. switching-policy independence: dynamic mines bit-identically and
# the report carries the policy + a consistent ledger
miner4 = ShardedMiner(config=cfg, policy="dynamic", verify_rounds=True)
res4 = miner4.run(T)
led = res4.report.ledger
out["dynamic_parity_ok"] = (res4.supports == single.supports
                            and res4.rules == single.rules
                            and res4.report.policy == "dynamic")
out["ledger_ok"] = (led is not None
                    and abs(led.total_energy_j
                            - res4.report.total_energy_j) < 1e-9
                    and led.n_phases >= 2 * res4.report.n_rounds
                    and led.total_time_s > 0)

print("RESULT" + json.dumps({k: bool(v) for k, v in out.items()}))
'''


@pytest.fixture(scope="module")
def mining_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_run_sharded_matches_simulated_cluster(mining_results):
    assert mining_results["parity_value_ok"]


def test_run_sharded_reports_energy(mining_results):
    assert mining_results["sharded_energy_ok"]
    assert mining_results["sharded_makespan_ok"]


def test_sharded_miner_matches_single_device(mining_results):
    assert mining_results["mining_supports_ok"]
    assert mining_results["mining_rules_ok"]
    assert mining_results["mining_report_ok"]


def test_device_loss_triggers_replan(mining_results):
    assert mining_results["replan_result_ok"]
    assert mining_results["replan_counts_ok"]
    assert mining_results["replan_gating_ok"]


def test_heterogeneous_split_follows_speeds(mining_results):
    assert mining_results["hetero_split_ok"]


def test_dynamic_policy_mines_identically(mining_results):
    assert mining_results["dynamic_parity_ok"]


def test_report_totals_come_from_the_ledger(mining_results):
    assert mining_results["ledger_ok"]


# ---- host-side plan math (no devices needed) ------------------------------

def test_plan_shard_rows_proportional_and_exact():
    from repro.data.sharding import plan_shard_rows
    prof = HeterogeneityProfile.paper()          # 80/120/200/400
    rows = plan_shard_rows(prof, 2048, row_block=8)
    assert rows.sum() == 2048
    assert (rows % 8 == 0).all()
    assert rows[3] == rows.max()                 # fastest core, most rows
    # ~proportional: within one block of the exact share
    shares = prof.shares() * 2048
    assert (np.abs(rows - shares) <= 8).all()


def test_plan_shard_rows_dead_ranks_get_zero():
    from repro.data.sharding import plan_shard_rows
    prof = HeterogeneityProfile.homogeneous(4, 100.0)
    alive = np.array([True, False, True, True])
    rows = plan_shard_rows(prof, 999, row_block=8, alive=alive)
    assert rows[1] == 0
    assert rows.sum() == 1000                    # ceil to a block multiple
    with pytest.raises(RuntimeError):
        plan_shard_rows(prof, 100, alive=np.zeros(4, bool))


def test_shard_bitmap_layout_and_count_moves():
    from repro.distributed.mining import (count_moves, plan_shards,
                                          shard_bitmap)
    prof = HeterogeneityProfile.paper()
    T = np.arange(64 * 4, dtype=np.uint8).reshape(64, 4) % 2
    plan = plan_shards(prof, 64, row_block=8)
    S = shard_bitmap(T, plan)
    assert S.shape == (plan.n_shards * plan.width, 4)
    # zero-padding is inert: global column sums survive the re-layout
    assert (S.sum(axis=0) == T.sum(axis=0)).all()
    # kill the fastest rank: its blocks re-issue, others may switch
    alive = np.array([True, True, True, False])
    plan2 = plan_shards(prof, 64, row_block=8, alive=alive)
    switches, reissued = count_moves(plan, plan2)
    assert reissued == plan.rows[3] // plan.row_block
    assert plan2.rows[3] == 0
    S2 = shard_bitmap(T, plan2)
    assert (S2.sum(axis=0) == T.sum(axis=0)).all()
