"""The Eclat vertical-mining plane: bit-identical parity with the Apriori
pipeline (the backends' contract), the sparse CSR slab round trips, the
cost-model auto-selection, and the autotune degradation ladder for the
``intersect_count`` kernel."""
import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori_bruteforce
from repro.data.baskets import BasketConfig, generate_baskets, sparse_baskets
from repro.data.sparse import SparseSlab, density_stats, pack_tid_columns
from repro.kernels.autotune.cache import AutotuneCache, resolve_config
from repro.launch.tuning import default_config
from repro.mining import (AlgorithmCostModel, EclatMiner, make_miner,
                          select_algorithm)
from repro.pipeline import MarketBasketPipeline, PipelineConfig

PROFILE = HeterogeneityProfile.paper


def _cfg(**kw):
    kw.setdefault("min_support", 0.05)
    kw.setdefault("n_tiles", 8)
    return PipelineConfig(**kw)


def _dense(n_tx=600, n_items=48, seed=0):
    return generate_baskets(BasketConfig(n_tx=n_tx, n_items=n_items,
                                         seed=seed))


# ---------------------------------------------------------------------------
# parity: eclat == apriori == bruteforce, bit for bit
# ---------------------------------------------------------------------------

def test_eclat_matches_apriori_and_bruteforce():
    T = _dense()
    cfg = _cfg()
    apriori = MarketBasketPipeline(PROFILE(), cfg).run(T)
    eclat = EclatMiner(PROFILE(), cfg).run(T)
    assert eclat.supports == apriori.supports
    assert eclat.rules == apriori.rules
    assert eclat.report.algorithm == "eclat"
    want = apriori_bruteforce(T, cfg.abs_support(T.shape[0]), max_k=8)
    assert eclat.supports == want


@pytest.mark.parametrize("policy", ["dynamic", "costmodel"])
def test_eclat_parity_under_every_policy(policy):
    T = _dense(400, 32, seed=2)
    cfg = _cfg(policy=policy)
    apriori = MarketBasketPipeline(PROFILE(), cfg).run(T)
    eclat = EclatMiner(PROFILE(), cfg).run(T)
    assert eclat.supports == apriori.supports
    assert eclat.rules == apriori.rules


def test_eclat_edge_no_frequent_items():
    T = _dense(100, 16, seed=1)
    cfg = _cfg(min_support=1.0)         # support in *every* transaction
    eclat = EclatMiner(PROFILE(), cfg).run(T)
    apriori = MarketBasketPipeline(PROFILE(), cfg).run(T)
    assert eclat.supports == apriori.supports
    assert eclat.rules == [] == apriori.rules


def test_eclat_edge_singleton_survivor():
    # exactly one frequent item: no pairs to intersect, no rules
    T = np.zeros((40, 8), np.uint8)
    T[:, 3] = 1
    T[:5, 0] = 1
    cfg = _cfg(min_support=0.5)
    eclat = EclatMiner(PROFILE(), cfg).run(T)
    assert eclat.supports == {(3,): 40}
    assert eclat.rules == []


def test_eclat_edge_all_frequent():
    # every item in every basket: the lattice saturates at max_k
    T = np.ones((30, 5), np.uint8)
    cfg = _cfg(min_support=0.9, max_k=3)
    eclat = EclatMiner(PROFILE(), cfg).run(T)
    apriori = MarketBasketPipeline(PROFILE(), cfg).run(T)
    assert eclat.supports == apriori.supports
    assert all(v == 30 for v in eclat.supports.values())
    assert max(len(c) for c in eclat.supports) == 3


def test_eclat_accepts_id_lists_and_slab():
    baskets = [[0, 2, 5], [2, 5], [0, 5], [5], [0, 2]] * 20
    slab = SparseSlab.from_baskets(baskets, n_items=8)
    cfg = _cfg(min_support=0.3)
    from_lists = EclatMiner(PROFILE(), cfg).run(baskets)
    from_slab = EclatMiner(PROFILE(), cfg).run(slab)
    oracle = MarketBasketPipeline(PROFILE(), cfg).run(baskets)
    assert from_lists.supports == from_slab.supports == oracle.supports
    assert from_lists.rules == from_slab.rules == oracle.rules


def test_eclat_sparse_input_never_densifies(monkeypatch):
    slab = SparseSlab.from_baskets(
        sparse_baskets(300, 256, seed=4), n_items=256)
    monkeypatch.setattr(
        SparseSlab, "to_dense",
        lambda self: (_ for _ in ()).throw(
            AssertionError("eclat densified the sparse slab")))
    res = EclatMiner(PROFILE(), _cfg(min_support=0.02)).run(slab)
    assert res.report.algorithm == "eclat"
    assert res.report.n_itemsets > 0


# ---------------------------------------------------------------------------
# sparse slab round trips
# ---------------------------------------------------------------------------

def test_sparse_slab_round_trip_exact():
    T = _dense(130, 33, seed=5)
    slab = SparseSlab.from_dense(T)
    np.testing.assert_array_equal(slab.to_dense(), T)
    assert slab.nnz == int(T.sum())
    # id-list construction is equivalent to dense construction
    baskets = [list(np.flatnonzero(row)) for row in T]
    slab2 = SparseSlab.from_baskets(baskets, n_items=T.shape[1])
    np.testing.assert_array_equal(slab2.to_dense(), T)


def test_sparse_slab_tid_columns_match_dense_packing():
    T = _dense(100, 40, seed=6)
    got = SparseSlab.from_dense(T).tid_columns()
    want = pack_tid_columns(T)
    np.testing.assert_array_equal(got, want)
    # bit (item i, tx t) lives at word t >> 5, bit t & 31
    for i, t in ((0, 0), (7, 33), (39, 99)):
        bit = (int(got[i, t >> 5]) >> (t & 31)) & 1
        assert bit == int(T[t, i])


def test_density_stats_agree_across_input_forms():
    T = _dense(90, 24, seed=7)
    slab = SparseSlab.from_dense(T)
    baskets = [list(np.flatnonzero(row)) for row in T]
    for form in (T, slab, baskets):
        s = density_stats(form)
        assert (s.n_tx, s.n_items, s.nnz) == (90, 24, int(T.sum()))
        np.testing.assert_array_equal(s.item_counts, T.sum(axis=0))


# ---------------------------------------------------------------------------
# auto-selection
# ---------------------------------------------------------------------------

def test_auto_selection_scripted_rates_force_each_algorithm():
    T = _dense(256, 32, seed=8)
    # eclat's kernel runs at datasheet rates while apriori's crawls → eclat
    slow, fast = (1e3, 1e3), (1e15, 1e15)
    pick_e = select_algorithm(T, 13, model=AlgorithmCostModel(
        {"support_count": slow, "intersect_count": fast}))
    assert pick_e.algorithm == "eclat"
    pick_a = select_algorithm(T, 13, model=AlgorithmCostModel(
        {"support_count": fast, "intersect_count": slow}))
    assert pick_a.algorithm == "apriori"
    # the evidence trail carries both priced costs and the features
    assert pick_e.est_cost_s["eclat"] < pick_e.est_cost_s["apriori"]
    assert pick_a.features["n_tx"] == 256.0


def test_make_miner_routes_auto_through_the_choice():
    T = _dense(300, 32, seed=9)
    model = AlgorithmCostModel({"support_count": (1e3, 1e3),
                                "intersect_count": (1e15, 1e15)})
    miner, choice = make_miner(T, profile=PROFILE(),
                               config=_cfg(algorithm="auto"), model=model)
    assert isinstance(miner, EclatMiner)
    assert choice is not None and choice.algorithm == "eclat"
    assert "auto-selected eclat" in choice.summary()
    # explicit algorithms return no choice
    miner2, choice2 = make_miner(T, profile=PROFILE(),
                                 config=_cfg(algorithm="apriori"))
    assert isinstance(miner2, MarketBasketPipeline) and choice2 is None


def test_auto_parity_with_apriori_oracle():
    T = _dense(500, 40, seed=10)
    cfg = _cfg(algorithm="auto")
    miner, choice = make_miner(T, profile=PROFILE(), config=cfg)
    res = miner.run(T)
    oracle = MarketBasketPipeline(PROFILE(), _cfg()).run(T)
    assert res.supports == oracle.supports
    assert res.rules == oracle.rules
    assert choice.algorithm in ("apriori", "eclat")


# ---------------------------------------------------------------------------
# autotune degradation: a cold cache prices/configures, never raises
# ---------------------------------------------------------------------------

def test_intersect_count_cold_cache_degrades_to_default():
    empty = AutotuneCache()
    cfg = resolve_config("intersect_count", (512, 128), empty)
    assert cfg == default_config("intersect_count", (512, 128))
    assert cfg["variant"] == "packed" and cfg["bm"] >= 1


def test_cost_model_cold_cache_degrades_to_roofline():
    model = AlgorithmCostModel.from_autotune(cache=AutotuneCache())
    assert model.cost_source["intersect_count"] == "roofline"
    assert model.cost_source["support_count"] == "roofline"
    choice = model.estimate(density_stats(_dense(200, 24, seed=11)), 10)
    assert choice.algorithm in ("apriori", "eclat")   # priced, not raised


def test_checked_in_cache_covers_intersect_count():
    from repro.kernels.autotune.cache import default_cache
    cache = default_cache()
    assert any(k.startswith("intersect_count|") for k in cache.entries), \
        "run the intersect_count sweep into the checked-in cache"
