"""Trip-count-aware HLO cost model: exactness on known programs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze
from repro.launch.roofline import parse_collectives


W = jnp.zeros((128, 128), jnp.float32)


def _cost(f, *args):
    txt = jax.jit(f).lower(*args).compile().as_text()
    return analyze(txt)


def test_unrolled_matmul_flops_exact():
    def f(x):
        for _ in range(10):
            x = x @ W
        return x
    c = _cost(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    assert c.flops == pytest.approx(10 * 2 * 128 ** 3)


def test_scan_trip_count_multiplied():
    def f(x):
        return jax.lax.scan(lambda c, _: (c @ W, None), x, None, length=10)[0]
    c = _cost(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    assert c.flops == pytest.approx(10 * 2 * 128 ** 3)
    assert c.unknown_trip_loops == 0


def test_nested_scans_multiply():
    def f(x):
        def outer(c, _):
            d = jax.lax.scan(lambda e, _: (e @ W, None), c, None, length=5)[0]
            return d, None
        return jax.lax.scan(outer, x, None, length=4)[0]
    c = _cost(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    assert c.flops == pytest.approx(20 * 2 * 128 ** 3)


def test_traffic_nonzero_and_scales_with_trips():
    def f1(x):
        return jax.lax.scan(lambda c, _: (c @ W, None), x, None, length=2)[0]
    def f2(x):
        return jax.lax.scan(lambda c, _: (c @ W, None), x, None, length=20)[0]
    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c1, c2 = _cost(f1, s), _cost(f2, s)
    assert c2.traffic_bytes > 5 * c1.traffic_bytes


def test_collective_parse_on_sharded_program():
    import subprocess, sys, os, json
    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_cost import analyze
from repro.core.compat import make_mesh   # version-compat axis types
mesh = make_mesh((2, 4), ("data", "model"))
def f(x, w):
    return jnp.sum(x @ w)
g = jax.grad(f, argnums=1)
sh = lambda *s: NamedSharding(mesh, P(*s))
low = jax.jit(g, in_shardings=(sh("data", None), sh(None, "model"))).lower(
    jax.ShapeDtypeStruct((32, 64), jnp.float32),
    jax.ShapeDtypeStruct((64, 128), jnp.float32))
c = analyze(low.compile().as_text())
print("RESULT" + json.dumps({"coll": c.collective_bytes}))
'''
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    assert json.loads(line[6:])["coll"] > 0
