"""MB Scheduler property tests (hypothesis): assignment completeness, LPT
quality bounds, proportionality, rebalancing conservation, speculation."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.hetero import PAPER_CORES, HeterogeneityProfile
from repro.core.scheduler import MBScheduler, TaskSpec, simulate_makespan


@st.composite
def profiles(draw):
    n = draw(st.integers(2, 12))
    speeds = draw(st.lists(st.floats(0.1, 100.0), min_size=n, max_size=n))
    return HeterogeneityProfile(np.array(speeds))


@st.composite
def tile_cost_arrays(draw):
    n = draw(st.integers(1, 200))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["uniform", "skewed", "equal"]))
    if kind == "equal":
        return np.full(n, 10.0)
    if kind == "skewed":
        return rng.zipf(1.7, n).astype(np.float64)
    return rng.uniform(1, 100, n)


@settings(max_examples=50, deadline=None)
@given(profiles(), tile_cost_arrays(),
       st.sampled_from(["lpt", "proportional", "equal"]))
def test_every_tile_assigned_exactly_once(profile, costs, policy):
    sched = MBScheduler(profile, policy=policy)
    task = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=len(costs))
    asg = sched.assign_parallel(task, costs)
    seen = sorted(t for ts in asg.tiles_of for t in ts)
    assert seen == list(range(len(costs)))


@settings(max_examples=50, deadline=None)
@given(profiles(), tile_cost_arrays())
def test_lpt_quality_bound(profile, costs):
    """Greedy EFT on uniform machines has makespan <= 2x the lower bound
    max(total/Σspeed, max_tile/max_speed)."""
    sched = MBScheduler(profile, policy="lpt")
    task = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=len(costs))
    asg = sched.assign_parallel(task, costs)
    lb = sched.makespan_lower_bound(costs)
    assert asg.makespan <= 2.0 * lb + 1e-9


@settings(max_examples=50, deadline=None)
@given(profiles(), tile_cost_arrays())
def test_lpt_never_worse_than_equal_split(profile, costs):
    t = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=len(costs))
    m_lpt = MBScheduler(profile, "lpt").assign_parallel(t, costs).makespan
    m_eq = MBScheduler(profile, "equal").assign_parallel(t, costs).makespan
    assert m_lpt <= m_eq + 1e-9


@settings(max_examples=40, deadline=None)
@given(profiles(), st.integers(10, 400))
def test_proportional_shares(profile, n_tiles):
    """Uniform tiles: per-device tile counts within 1 of speed-proportional."""
    sched = MBScheduler(profile, policy="proportional")
    task = TaskSpec("t", float(n_tiles), parallel=True, n_tiles=n_tiles)
    asg = sched.assign_parallel(task)
    shares = profile.shares() * n_tiles
    for d, tiles in enumerate(asg.tiles_of):
        assert abs(len(tiles) - shares[d]) <= 1.0 + 1e-9


def test_paper_four_core_example():
    """Paper §V: 80/120/200/400 cores.  Equal split is 2.5x slower than a
    proportional split (800/(4*80) = 2.5)."""
    profile = HeterogeneityProfile.paper()
    costs = np.full(80, 10.0)
    t = TaskSpec("mba", 800.0, parallel=True, n_tiles=80)
    m_eq = MBScheduler(profile, "equal").assign_parallel(t, costs).makespan
    m_prop = MBScheduler(profile, "proportional").assign_parallel(t, costs).makespan
    assert m_prop == pytest.approx(800.0 / sum(PAPER_CORES), rel=0.1)
    assert m_eq / m_prop == pytest.approx(2.5, rel=0.1)


def test_serial_task_picks_best_core_and_gates_rest():
    profile = HeterogeneityProfile.paper()
    sched = MBScheduler(profile)
    asg = sched.assign_serial(TaskSpec("serial", 100.0, parallel=False))
    assert asg.serial_device == 3          # the 400 core
    assert sorted(asg.gated) == [0, 1, 2]
    assert asg.makespan == pytest.approx(100.0 / 400.0)


@settings(max_examples=30, deadline=None)
@given(profiles(), tile_cost_arrays())
def test_rebalance_conserves_tiles(profile, costs):
    sched = MBScheduler(profile, policy="lpt")
    task = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=len(costs))
    asg = sched.assign_parallel(task, costs)
    # dynamic switching: speeds change, re-plan
    profile.observe(0, work_done=1.0, seconds=50.0)
    new, moved = sched.rebalance(task, asg, costs)
    seen = sorted(t for ts in new.tiles_of for t in ts)
    assert seen == list(range(len(costs)))
    assert moved == sched.switches


def test_ewma_observe_moves_towards_rate():
    p = HeterogeneityProfile(np.array([10.0, 10.0]))
    p.observe(0, work_done=100.0, seconds=100.0)   # rate 1.0 << 10
    assert p.speeds[0] < 10.0
    assert p.speeds[1] == 10.0


# ---------------------------------------------------------------------------
# scheduler invariants (runtime-refactor satellite): for every policy and
# after rebalance / speculate+apply_moves, tiles_of stays an exact partition
# of the tile set and reported switches equal actual owner changes
# ---------------------------------------------------------------------------

def _assert_exact_partition(asg, n_tiles):
    seen = sorted(t for ts in asg.tiles_of for t in ts)
    assert seen == list(range(n_tiles)), "tiles lost or duplicated"


@settings(max_examples=50, deadline=None)
@given(profiles(), tile_cost_arrays(),
       st.sampled_from(["lpt", "proportional", "equal"]),
       st.integers(0, 2**31 - 1))
def test_partition_invariant_survives_rebalance(profile, costs, policy, seed):
    sched = MBScheduler(profile, policy=policy)
    task = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=len(costs))
    asg = sched.assign_parallel(task, costs)
    _assert_exact_partition(asg, len(costs))
    # dynamic switching: a random speed observation, then a re-plan
    rng = np.random.default_rng(seed)
    dev = int(rng.integers(profile.n))
    sched.profile.observe(dev, work_done=float(rng.uniform(0.1, 100.0)),
                          seconds=float(rng.uniform(0.1, 100.0)))
    sw0 = sched.switches
    new, moved = sched.rebalance(task, asg, costs)
    _assert_exact_partition(new, len(costs))
    before, after = asg.owner_of(), new.owner_of()
    actual_moves = sum(1 for t in after if after[t] != before[t])
    assert moved == actual_moves                 # reported == actual
    assert sched.switches - sw0 == moved         # lifetime counter agrees


@settings(max_examples=50, deadline=None)
@given(profiles(), tile_cost_arrays(),
       st.sampled_from(["lpt", "proportional", "equal"]),
       st.integers(0, 2**31 - 1))
def test_partition_invariant_survives_speculate_apply(profile, costs,
                                                      policy, seed):
    sched = MBScheduler(profile, policy=policy)
    task = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=len(costs))
    asg = sched.assign_parallel(task, costs)
    rng = np.random.default_rng(seed)
    progress = rng.uniform(0.0, 1.0, profile.n)
    progress[rng.integers(profile.n)] = 1.0      # at least one idle helper
    sw0 = sched.switches
    moves = sched.speculate(asg, progress)
    assert sched.switches - sw0 == len(moves)    # reported == actual
    applied = sched.apply_moves(asg, moves, costs)
    _assert_exact_partition(applied, len(costs))
    before, after = asg.owner_of(), applied.owner_of()
    assert sum(1 for t in after if after[t] != before[t]) == len(moves)
    # re-issued tiles really left the straggler: a repeat speculation (with
    # the helpers re-measured as finished, as a fresh checkpoint would see
    # them) can never pick the same tiles again — the satellite bug was that
    # an unmutated assignment re-issued them forever
    progress2 = progress.copy()
    progress2[[h for _, h in moves]] = 1.0
    again = sched.speculate(applied, progress2)
    assert {t for t, _ in moves}.isdisjoint({t for t, _ in again})


def test_makespan_simulation_matches_estimate():
    profile = HeterogeneityProfile.paper()
    costs = np.random.default_rng(0).uniform(1, 20, 37)
    sched = MBScheduler(profile, policy="lpt")
    asg = sched.assign_parallel(
        TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=37), costs)
    assert simulate_makespan(asg, costs, profile) == pytest.approx(asg.makespan)
