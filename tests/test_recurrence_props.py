"""Property tests: the fast full-sequence recurrence forms (chunked WKV,
associative/chunked selective scan) match their sequential definitions on
hypothesis-generated shapes/values — the §Perf A correctness backstop."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models.rwkv6 import _wkv_chunked, _wkv_scan
from repro.models.ssm import _selective_scan


@st.composite
def wkv_inputs(draw):
    B = draw(st.integers(1, 2))
    T = draw(st.sampled_from([32, 64, 96]))
    H = draw(st.integers(1, 3))
    n = draw(st.sampled_from([8, 16]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    r, k, v = (jnp.asarray(rng.standard_normal((B, T, H, n)) * 0.5, jnp.float32)
               for _ in range(3))
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((B, T, H, n)) - 1.0)),
                    jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, n)) * 0.5, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, n, n)) * 0.1, jnp.float32)
    return r, k, v, w, u, s0


@settings(max_examples=10, deadline=None)
@given(wkv_inputs(), st.sampled_from([16, 32]))
def test_wkv_chunked_equals_sequential(inputs, chunk):
    r, k, v, w, u, s0 = inputs
    y1, s1 = _wkv_scan(r, k, v, w, u, s0)
    y2, s2 = _wkv_chunked(r, k, v, w, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3)


@st.composite
def ssm_inputs(draw):
    B = draw(st.integers(1, 2))
    S = draw(st.sampled_from([32, 64, 256]))
    di = draw(st.sampled_from([8, 32]))
    N = draw(st.sampled_from([4, 16]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((B, S, di)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, di))) * 0.3 + 0.01,
                     jnp.float32)
    A = -jnp.asarray(np.abs(rng.standard_normal((di, N))) + 0.05, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    D = jnp.asarray(rng.standard_normal(di), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, di, N)) * 0.2, jnp.float32)
    return u, dt, A, Bm, Cm, D, h0


@settings(max_examples=10, deadline=None)
@given(ssm_inputs(), st.sampled_from(["associative", "chunked"]))
def test_selective_scan_impls_equal(inputs, impl):
    u, dt, A, Bm, Cm, D, h0 = inputs
    y1, h1 = _selective_scan(u, dt, A, Bm, Cm, D, h0, impl="scan")
    y2, h2 = _selective_scan(u, dt, A, Bm, Cm, D, h0, impl=impl)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=5e-4, rtol=1e-3)
