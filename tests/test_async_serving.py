"""Async serving plane: scripted-clock admission/coalescing/SLO unit tests,
exactly-once drain delivery, async == closed-loop == brute-force parity
under both switching policies, PlaneReport protocol conformance, and the
threaded wall-clock mode."""
import numpy as np
import pytest

from repro.data.baskets import BasketConfig, generate_baskets
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.runtime import PlaneReport
from repro.serving import (AsyncServer, BucketLadder, Handle, Query,
                           RecommendationEngine, RequestQueue, RuleIndex,
                           ServingConfig, ShedError, SloGovernor,
                           VirtualClock, WallClock, recommend_bruteforce)
from repro.serving.cache import basket_key


@pytest.fixture(scope="module")
def mined():
    """One small mined corpus shared by the async serving tests."""
    T = generate_baskets(BasketConfig(n_tx=500, n_items=32, n_patterns=5,
                                      pattern_len=3, pattern_prob=0.5,
                                      seed=3))
    res = MarketBasketPipeline(
        config=PipelineConfig(min_support=0.05, min_confidence=0.5,
                              n_tiles=4)).run(T)
    assert res.rules, "fixture corpus must mine a non-trivial rule set"
    return T, res


def make_engine(res, policy="static", buckets=(1, 8, 64), cache_size=0,
                slo_ms=0.0, n_items=32):
    return RecommendationEngine(
        RuleIndex.build(res.rules, n_items),
        config=ServingConfig(k=5, batch_buckets=buckets, data_plane="ref",
                             cache_size=cache_size, policy=policy,
                             slo_ms=slo_ms))


def queries_of(T, n):
    return [Query.of(list(np.nonzero(row)[0])) for row in T[:n]]


def handle_of(rid, arrival_s, n_items=8):
    bits = np.zeros(n_items, dtype=np.uint8)
    return Handle(rid=rid, query=Query([0]), arrival_s=arrival_s,
                  bits=bits, key=basket_key(bits))


# ---------------------------------------------------------------------------
# admission pieces under a scripted clock (no engine, no jax)
# ---------------------------------------------------------------------------

def test_request_queue_fifo_and_arrival_gating():
    q = RequestQueue()
    for rid, t in enumerate([0.0, 1.0, 2.0]):
        q.append(handle_of(rid, t))
    assert q.next_arrival() == 0.0
    # only the contiguous head that has arrived by now is taken
    got = q.take_ready(now=1.5, limit=10)
    assert [h.rid for h in got] == [0, 1]
    assert len(q) == 1 and q.next_arrival() == 2.0
    # the limit is the slot count: a full queue yields at most `limit`
    for rid in range(3, 9):
        q.append(handle_of(rid, 2.0))
    got = q.take_ready(now=5.0, limit=4)
    assert [h.rid for h in got] == [2, 3, 4, 5]


def test_bucket_ladder_pick_coalesces_to_smallest_cover():
    ladder = BucketLadder([64, 1, 8, 8])      # deduped + sorted
    assert ladder.buckets == (1, 8, 64)
    assert [ladder.pick(n) for n in (1, 2, 8, 9, 64)] == [1, 8, 8, 64, 64]
    with pytest.raises(ValueError):
        ladder.pick(65)
    with pytest.raises(ValueError):
        ladder.pick(0)


def test_bucket_ladder_warm_and_ewma_projection():
    ladder = BucketLadder([1, 4])
    clock = iter(np.arange(0.0, 10.0, 0.5))   # scripted timer: 0.5s/rung
    warmed = []
    total = ladder.warm(warmed.append, lambda: float(next(clock)))
    assert warmed == [1, 4] and total == pytest.approx(1.0)
    assert ladder.warmed and ladder.state[1].warm_wall_s == 0.5
    # nothing measured yet -> projections come from warm-free fallback (0)
    # until observe() feeds real steps
    ladder.observe(1, 2.0)
    assert ladder.projected_step_s(1) == pytest.approx(2.0)
    # unmeasured rung projects from the nearest measured one, ratio-scaled
    assert ladder.projected_step_s(4) == pytest.approx(8.0)
    ladder.observe(1, 1.0)                    # EWMA alpha=0.3
    assert ladder.projected_step_s(1) == pytest.approx(0.3 * 1.0 + 0.7 * 2.0)


def test_slo_governor_sheds_at_scripted_threshold():
    ladder = BucketLadder([1, 8])
    gov = SloGovernor(slo_s=1.0, ladder=ladder)
    late, fresh = handle_of(0, 0.0), handle_of(1, 0.7)
    # no measurements yet -> the governor only acts on evidence: admit all
    admit, shed = gov.split(now=0.8, ready=[late, fresh])
    assert [h.rid for h in admit] == [0, 1] and not shed
    # scripted step walls: one step on the covering bucket takes 0.5s
    ladder.observe(8, 0.5)
    admit, shed = gov.split(now=0.8, ready=[late, fresh])
    # late: 0.8 queue delay + 0.5 step = 1.3 > 1.0 -> shed;
    # fresh: 0.1 + 0.5 = 0.6 <= 1.0 -> admitted
    assert [h.rid for h in shed] == [0]
    assert [h.rid for h in admit] == [1]
    assert gov.n_shed == 1
    # slo_s <= 0 disables shedding entirely
    assert SloGovernor(0.0, ladder).split(5.0, [late])[1] == []


def test_handle_finishes_exactly_once():
    h = handle_of(0, 0.0)
    with pytest.raises(RuntimeError, match="pending"):
        h.result()
    h._finish("done", [(1, 0.5)], t_done=2.0)
    assert h.done() and h.latency_s == pytest.approx(2.0)
    assert h.result() == [(1, 0.5)]
    with pytest.raises(AssertionError):      # terminal transition is single
        h._finish("done", [], 3.0)
    s = handle_of(1, 0.0)
    s._finish("shed", None, 1.0)
    with pytest.raises(ShedError):
        s.result()


def test_query_coercion_forms():
    q = Query.of([3, 7])
    assert q.payload == [3, 7] and q.rid is None
    q = Query.of({"items": [3, 7], "id": 42, "arrival_s": 1.5})
    assert (q.payload, q.rid, q.arrival_s) == ([3, 7], 42, 1.5)
    assert Query.of(q) is q                   # idempotent
    with pytest.raises(ValueError, match="items"):
        Query.of({"basket": [1]})
    with pytest.raises(ValueError, match="allow only"):
        Query.of({"items": [1], "priority": 9})


def test_clock_domains():
    v = VirtualClock()
    assert v.domain == "sim" and v.now() == 0.0
    assert v.advance(2.0) == 2.0
    assert v.advance(1.0) == 2.0              # never backwards
    w = WallClock()
    assert w.domain == "wall" and w.advance(1e9) < 1.0   # advance is a no-op


# ---------------------------------------------------------------------------
# the drain loop on a real engine (virtual clock: fully deterministic)
# ---------------------------------------------------------------------------

def test_admission_fills_slots_then_runs(mined):
    T, res = mined
    engine = make_engine(res, buckets=(1, 2, 4))
    server = AsyncServer(engine, slots=2)
    for q in queries_of(T, 5):                # all arrive at t=0
        server.submit(q)
    assert len(server.drain()) == 5
    rep = server.take_report()
    # 5 ready requests through 2 slots = steps of 2, 2, 1
    assert rep.n_steps == 3
    assert rep.bucket_counts == {2: 2, 1: 1}
    assert rep.slot_occupancy == pytest.approx(np.mean([1.0, 1.0, 0.5]))
    assert rep.batch_fill == pytest.approx(1.0)   # every bucket exactly full


def test_coalescing_never_strands_a_request(mined):
    T, res = mined
    engine = make_engine(res, buckets=(1, 8, 64))
    server = AsyncServer(engine)
    # a lone request, then long-gapped stragglers: each must be scored on
    # the smallest covering bucket as soon as it arrives, never held for
    # a full batch
    arrivals = [0.0, 100.0, 200.0, 300.0]
    handles = [server.submit(q, arrival_s=t)
               for q, t in zip(queries_of(T, 4), arrivals)]
    assert len(server.drain()) == 4
    rep = server.take_report()
    assert all(h.status == "done" for h in handles)
    assert rep.bucket_counts == {1: 4}        # coalesced, not padded to 64
    for h in handles:                         # nobody waited on a neighbor
        assert h.latency_s < 100.0


def test_drain_delivers_every_request_exactly_once(mined):
    T, res = mined
    engine = make_engine(res)
    server = AsyncServer(engine)
    qs = queries_of(T, 6)
    first = [server.submit(q) for q in qs[:4]]
    got1 = server.drain()
    assert got1 == first                      # submission order
    second = [server.submit(q) for q in qs[4:]]
    got2 = server.drain()
    assert got2 == second                     # no re-delivery of the first 4
    assert server.drain() == []               # idle drain yields nothing
    rids = [h.rid for h in got1 + got2]
    assert len(rids) == len(set(rids)) == 6


def test_slo_shedding_on_the_server(mined):
    T, res = mined
    engine = make_engine(res, slo_ms=1000.0)
    server = AsyncServer(engine)
    qs = queries_of(T, 3)
    # script the projection: a step on any rung takes 0.5s
    for b in server.ladder.buckets:
        server.ladder.observe(b, 0.5)
    # one request already 0.8s old when the loop first runs, one fresh
    late = server.submit(qs[0], arrival_s=0.0)
    fresh = server.submit(qs[1], arrival_s=0.8)
    server.clock.advance(0.8)
    server.drain()
    assert late.status == "shed" and fresh.status == "done"
    with pytest.raises(ShedError, match="shed"):
        late.result()
    rep = server.take_report()
    assert rep.n_shed == 1 and rep.n_completed == 1
    # the shed is a first-class priced phase in the ledger, kind="shed"
    sheds = rep.ledger.by_kind("shed")
    assert len(sheds) == 1 and sheds[0].energy_j > 0
    assert rep.shed_rate == pytest.approx(0.5)
    # a request submitted after load subsides is served normally
    ok = server.submit(qs[2])
    assert server.poll(ok) is not None


def test_async_matches_closed_loop_and_oracle_under_both_policies(mined):
    T, res = mined
    qs = queries_of(T, 48)
    rng = np.random.default_rng(11)
    arrivals = np.cumsum(rng.exponential(0.05, size=48))
    oracle = [recommend_bruteforce(res.rules, q.payload, 5) for q in qs]
    for policy in ("static", "dynamic"):
        closed, crep = make_engine(res, policy=policy).serve(qs, arrivals)
        engine = make_engine(res, policy=policy)
        server = AsyncServer(engine)
        handles = [server.submit(q, arrival_s=float(t))
                   for q, t in zip(qs, arrivals)]
        server.drain()
        rep = server.take_report()
        got = [h.result() for h in handles]
        assert got == closed == oracle, f"policy={policy}"
        # same trace, same loop: identical accounting, not just results
        assert rep.total_energy_j == pytest.approx(crep.energy_j)
        assert rep.total_switches == crep.switches
        assert rep.p99_latency_s == pytest.approx(crep.p99_latency_s)
        assert rep.ledger.n_phases == crep.ledger.n_phases
        assert set(p.kind for p in rep.ledger.phases) <= {"serial", "map"}
        assert engine.runtime.ledger.n_phases == 0   # slices fully taken


def test_ladder_rewarms_after_index_refresh(mined):
    T, res = mined
    engine = make_engine(res)
    server = AsyncServer(engine)
    v0 = server._warm_version
    assert server.ladder.warmed and v0 == engine.index.version
    h1 = server.submit(queries_of(T, 1)[0])
    assert server.poll(h1) is not None
    engine.refresh(RuleIndex.build(res.rules[: len(res.rules) // 2], 32))
    h2 = server.submit(queries_of(T, 1)[0])
    assert server.poll(h2) is not None
    assert server._warm_version == engine.index.version > v0
    rep = server.take_report()
    assert rep.index_version == engine.index.version


def test_engine_submit_poll_drain_surface(mined):
    T, res = mined
    engine = make_engine(res, cache_size=64)
    q = queries_of(T, 1)[0]
    h = engine.submit({"items": q.payload, "id": 99})
    assert h.rid == 99
    want = recommend_bruteforce(res.rules, q.payload, 5)
    assert engine.poll(h) == want
    h2 = engine.submit(q)                     # server-assigned rid moves on
    assert h2.rid > 99
    done = engine.drain()
    assert [x.rid for x in done] == [99, h2.rid]
    assert h2.result() == want


def test_plane_report_protocol_conformance(mined):
    T, res = mined
    engine = make_engine(res)
    _, srep = engine.serve(queries_of(T, 4))
    server = AsyncServer(engine)
    server.submit(queries_of(T, 1)[0])
    server.drain()
    arep = server.take_report()
    for report in (res.report, srep, arep):   # pipeline, serving, async
        assert isinstance(report, PlaneReport), type(report)
        assert report.total_time_s >= 0 and report.total_energy_j >= 0
        assert isinstance(report.summary(), str)
    from repro.streaming.miner import StreamingReport
    stream_rep = StreamingReport(backend="ref", policy="static", split="lpt",
                                 window=8, batch_size=4)
    assert isinstance(stream_rep, PlaneReport)


def test_threaded_wall_clock_mode(mined):
    T, res = mined
    qs = queries_of(T, 12)
    inline, _ = make_engine(res).serve(qs)
    engine = make_engine(res)
    with AsyncServer(engine) as server:       # start()s the drain thread
        handles = [server.submit(q) for q in qs]
        results = [h.result(timeout=30.0) for h in handles]
    assert results == inline                  # batching never changes answers
    rep = server.take_report()
    assert rep.clock == "wall"
    assert rep.n_completed == 12 and rep.n_shed == 0
    assert rep.p99_latency_s > 0
