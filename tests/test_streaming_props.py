"""Hypothesis property: incremental-vs-batch mining parity.

For random basket streams, window sizes and micro-batch sizes, the
StreamingMiner's supports and rules after K micro-batches must be
bit-identical to a one-shot MarketBasketPipeline over the equivalent
window — the exactness contract the delta algebra + negative-border
re-validation trigger guarantees (see repro/streaming/miner.py)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.pipeline import MarketBasketPipeline  # noqa: E402
from repro.streaming import (StreamingConfig, StreamingMiner,  # noqa: E402
                             TransactionStream)


@st.composite
def stream_cases(draw):
    n_items = draw(st.integers(4, 12))
    n_tx = draw(st.integers(1, 48))
    window = draw(st.integers(1, 24))
    batch = draw(st.integers(1, 16))
    density = draw(st.floats(0.1, 0.6))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    T = (rng.random((n_tx, n_items)) < density).astype(np.uint8)
    min_support = draw(st.sampled_from([0.1, 0.25, 0.5]))
    min_conf = draw(st.sampled_from([0.3, 0.6]))
    return T, window, batch, min_support, min_conf


@settings(max_examples=25, deadline=None)
@given(stream_cases())
def test_incremental_equals_batch_mining(case):
    T, window, batch, min_support, min_conf = case
    cfg = StreamingConfig(window=window, batch_size=batch,
                          min_support=min_support, min_confidence=min_conf,
                          n_tiles=2, data_plane="ref", power="none")
    miner = StreamingMiner(T.shape[1], config=cfg)
    miner.run(TransactionStream(T, batch))
    rows = miner.window.rows_raw()
    assert miner.window.n == min(T.shape[0], window)
    pipe = MarketBasketPipeline(config=cfg.pipeline_config()).run(rows)
    assert miner.supports == pipe.supports
    assert miner.rules == pipe.rules


@settings(max_examples=10, deadline=None)
@given(stream_cases(), st.sampled_from(["static", "dynamic"]))
def test_parity_is_policy_independent(case, policy):
    """Scheduling must never change what gets mined, only when/where."""
    T, window, batch, min_support, min_conf = case
    cfg = StreamingConfig(window=window, batch_size=batch,
                          min_support=min_support, min_confidence=min_conf,
                          n_tiles=2, data_plane="ref", power="none",
                          policy=policy)
    miner = StreamingMiner(T.shape[1], config=cfg)
    miner.run(TransactionStream(T, batch))
    pipe = MarketBasketPipeline(
        config=cfg.pipeline_config()).run(miner.window.rows_raw())
    assert miner.supports == pipe.supports
    assert miner.rules == pipe.rules
