"""Differential fuzz: every Apriori kernel variant vs the jitted ref vs a
pure-Python (numpy) oracle, under EXACT equality.

The counts are int32 and the rule scores are f32 ``match * conf`` with an
exact 0/1 match factor, so all backends must agree bit-for-bit — any
tolerance would let a subtly-wrong tile config ship as "close enough".
The same bar the autotuner applies per swept config
(:mod:`repro.kernels.autotune.tuner`) is applied here across
hypothesis-generated shapes, densities and tile configs, plus the edge
cases the planes rely on: ``sizes = -1`` padding rows that must never
match, empty candidate/rule sets, and single-word item universes
(``I <= 32``, one packed uint32 lane).
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.rule_match.ops import rule_topk
from repro.kernels.rule_match.ref import rule_scores_ref
from repro.kernels.support_count.ops import intersect_count, support_count
from repro.kernels.support_count.ref import (intersect_count_ref,
                                             support_count_ref)

# sampled (not arbitrary) dims: every distinct padded shape is a fresh XLA
# compile, so the strategy draws from a small lattice that still crosses
# the interesting boundaries (sub-lane, exact-lane, lane+1, multi-word)
_N_TX = (1, 7, 8, 64, 130)
_N_ITEMS = (1, 20, 32, 33, 128, 200)
_N_CAND = (0, 1, 5, 128, 200)
_TILES = (8, 64, 128, 256, 512)


def np_support_count(T, C):
    """The Python oracle: row t supports candidate c iff c ⊆ t."""
    T = np.asarray(T, np.int64)
    C = np.asarray(C, np.int64)
    dots = T @ C.T                                  # [N, M]
    sizes = C.sum(axis=1)
    return (dots == sizes[None, :]).sum(axis=0).astype(np.int32)


def np_rule_scores(Q, A, sizes, conf):
    """Python oracle for the serving scores: conf where A_r ⊆ q, else 0.
    Padding rows carry sizes = -1; dots are >= 0 so they can never match."""
    dots = np.asarray(Q, np.int64) @ np.asarray(A, np.int64).T
    match = dots == np.asarray(sizes, np.int64)[None, :]
    return (match * np.asarray(conf, np.float32)[None, :]).astype(np.float32)


@st.composite
def support_problems(draw):
    n = draw(st.sampled_from(_N_TX))
    i = draw(st.sampled_from(_N_ITEMS))
    m = draw(st.sampled_from(_N_CAND))
    seed = draw(st.integers(0, 2**31 - 1))
    density = draw(st.sampled_from([0.05, 0.3, 0.9]))
    rng = np.random.default_rng(seed)
    T = (rng.random((n, i)) < density).astype(np.uint8)
    C = np.zeros((m, i), np.uint8)
    for r in range(m):
        C[r, rng.choice(i, size=min(1 + r % 4, i), replace=False)] = 1
    tiles = {"bn": draw(st.sampled_from(_TILES)),
             "bm": draw(st.sampled_from(_TILES)),
             "bi": draw(st.sampled_from(_TILES))}
    return T, C, tiles


@settings(max_examples=25, deadline=None)
@given(support_problems())
def test_support_count_differential(problem):
    T, C, tiles = problem
    want = np_support_count(T, C)
    ref = np.asarray(support_count_ref(jnp.asarray(T), jnp.asarray(C)))
    np.testing.assert_array_equal(ref, want)        # jitted ref vs oracle
    for variant in ("packed", "mxu"):
        got = np.asarray(support_count(
            jnp.asarray(T), jnp.asarray(C),
            tuning={"variant": variant, **tiles}))
        np.testing.assert_array_equal(
            got, want, err_msg=f"variant={variant} tiles={tiles}")


def np_intersect_count(A, B):
    """Python oracle for the Eclat round kernel: popcount(A & B) per row,
    via unpackbits on the raw little-endian bytes (no popcount intrinsic)."""
    bits = np.unpackbits((np.asarray(A) & np.asarray(B)).view(np.uint8),
                         axis=1, bitorder="little")
    return bits.sum(axis=1).astype(np.int32)


@st.composite
def intersect_problems(draw):
    m = draw(st.sampled_from((0, 1, 5, 128, 200)))
    w = draw(st.sampled_from((1, 4, 128, 130)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    A, B = rng.integers(0, 2**32, size=(2, m, w), dtype=np.uint32)
    tiles = {"bm": draw(st.sampled_from(_TILES)),
             "bw": draw(st.sampled_from(_TILES))}
    return A, B, tiles


@settings(max_examples=25, deadline=None)
@given(intersect_problems())
def test_intersect_count_differential(problem):
    A, B, tiles = problem
    want = np_intersect_count(A, B)
    ref = np.asarray(intersect_count_ref(jnp.asarray(A), jnp.asarray(B)))
    np.testing.assert_array_equal(ref, want)        # jitted ref vs oracle
    got = np.asarray(intersect_count(jnp.asarray(A), jnp.asarray(B),
                                     tuning={"variant": "packed", **tiles}))
    np.testing.assert_array_equal(got, want, err_msg=f"tiles={tiles}")


@st.composite
def rule_problems(draw):
    b = draw(st.sampled_from((1, 3, 8, 16)))
    i = draw(st.sampled_from(_N_ITEMS))
    r = draw(st.sampled_from((0, 1, 5, 128, 200)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    Q = (rng.random((b, i)) < 0.3).astype(np.uint8)
    A = np.zeros((r, i), np.uint8)
    for row in range(r):
        A[row, rng.choice(i, size=min(1 + row % 3, i), replace=False)] = 1
    sizes = A.sum(axis=1).astype(np.float32)
    conf = (rng.random(r) * 0.9 + 0.1).astype(np.float32)
    cons = rng.integers(0, i, size=r).astype(np.int32)
    k = draw(st.sampled_from((1, 3, 5)))
    tiles = {"bb": draw(st.sampled_from(_TILES)),
             "br": draw(st.sampled_from(_TILES)),
             "bi": draw(st.sampled_from(_TILES))}
    return Q, A, sizes, conf, cons, min(k, i), tiles


@settings(max_examples=25, deadline=None)
@given(rule_problems())
def test_rule_topk_differential(problem):
    Q, A, sizes, conf, cons, k, tiles = problem
    n_items = Q.shape[1]
    args = (jnp.asarray(Q), jnp.asarray(A), jnp.asarray(sizes),
            jnp.asarray(conf), jnp.asarray(cons))
    ri, rs = rule_topk(*args, k=k, n_items=n_items, backend="ref")
    outs = {"ref": (np.asarray(ri), np.asarray(rs))}
    for variant in ("packed", "mxu"):
        gi, gs = rule_topk(*args, k=k, n_items=n_items, backend="pallas",
                           tuning={"variant": variant, **tiles})
        outs[variant] = (np.asarray(gi), np.asarray(gs))
    for variant, (gi, gs) in outs.items():
        np.testing.assert_array_equal(
            gi, outs["ref"][0], err_msg=f"items {variant} tiles={tiles}")
        np.testing.assert_array_equal(
            gs, outs["ref"][1], err_msg=f"scores {variant} tiles={tiles}")
    # and the jnp score oracle the ref backend folds through must itself
    # agree with the pure-Python one (closing the differential chain:
    # numpy == jnp ref scores; ref-backend top-k == both Pallas variants)
    np.testing.assert_array_equal(
        np.asarray(rule_scores_ref(jnp.asarray(Q), jnp.asarray(A),
                                   jnp.asarray(sizes), jnp.asarray(conf))),
        np_rule_scores(Q, A, sizes, conf))


# ---------------------------------------------------------------------------
# the planes' contract edges, pinned explicitly (fuzz can miss exact cases)
# ---------------------------------------------------------------------------

def test_support_count_empty_candidates():
    T = (np.random.default_rng(0).random((16, 64)) < 0.4).astype(np.uint8)
    out = np.asarray(support_count(jnp.asarray(T),
                                   jnp.asarray(np.zeros((0, 64), np.uint8))))
    assert out.shape == (0,) and out.dtype == np.int32


def test_rule_topk_empty_rules():
    Q = (np.random.default_rng(1).random((4, 32)) < 0.4).astype(np.uint8)
    empty = np.zeros((0, 32), np.uint8)
    for variant in ("packed", "mxu"):
        items, scores = rule_topk(
            jnp.asarray(Q), jnp.asarray(empty),
            jnp.asarray(np.zeros(0, np.float32)),
            jnp.asarray(np.zeros(0, np.float32)),
            jnp.asarray(np.zeros(0, np.int32)), k=3, n_items=32,
            backend="pallas",
            tuning={"variant": variant, "bb": 8, "br": 128, "bi": 128})
        assert (np.asarray(scores) <= 0.0).all()    # nothing can match


def test_rule_scores_padding_rows_never_match():
    """sizes = -1 rows (index padding) must score 0 even for an all-zero
    antecedent row against an empty query — the all-zero-matches-everything
    trap the -1 contract exists to close."""
    Q = np.zeros((2, 32), np.uint8)                 # empty baskets
    Q[1, :3] = 1
    A = np.zeros((128, 32), np.uint8)               # all rows all-zero
    sizes = np.full(128, -1.0, np.float32)
    conf = np.ones(128, np.float32)
    for variant in ("packed", "mxu"):
        got = rule_topk(
            jnp.asarray(Q), jnp.asarray(A), jnp.asarray(sizes),
            jnp.asarray(conf), jnp.asarray(np.zeros(128, np.int32)),
            k=3, n_items=32, backend="pallas",
            tuning={"variant": variant, "bb": 8, "br": 128, "bi": 128})[1]
        assert (np.asarray(got) <= 0.0).all(), variant


def test_single_word_universe_exact():
    """I <= 32: the packed layout is one uint32 word — the word-boundary
    edge where a shift/mask bug would first show."""
    rng = np.random.default_rng(7)
    for i in (1, 31, 32):
        T = (rng.random((24, i)) < 0.5).astype(np.uint8)
        C = np.zeros((8, i), np.uint8)
        for r in range(8):
            C[r, rng.choice(i, size=min(1 + r % 3, i), replace=False)] = 1
        want = np_support_count(T, C)
        for variant in ("packed", "mxu"):
            got = np.asarray(support_count(
                jnp.asarray(T), jnp.asarray(C),
                tuning={"variant": variant, "bn": 8, "bm": 128, "bi": 128}))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"I={i} {variant}")
