"""Association-rule generation (paper step 3) vs direct probability math."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; module skips cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.itemsets import apriori
from repro.core.rules import generate_rules


def db_with_implication(n=400, noise=0.05, seed=0):
    """Item 0 implies item 1 ~always; items 2,3 independent."""
    rng = np.random.default_rng(seed)
    T = np.zeros((n, 4), np.uint8)
    has0 = rng.random(n) < 0.4
    T[:, 0] = has0
    T[:, 1] = has0 | (rng.random(n) < noise)
    T[:, 2] = rng.random(n) < 0.3
    T[:, 3] = rng.random(n) < 0.3
    return T


def test_confidence_and_lift_exact():
    T = db_with_implication()
    res = apriori(T, min_support=10)
    rules = generate_rules(res, min_confidence=0.0)
    n = float(len(T))
    for r in rules:
        both = tuple(sorted(r.antecedent + r.consequent))
        s_both = res.supports[both]
        s_a = res.supports[r.antecedent]
        s_b = res.supports[r.consequent]
        assert r.confidence == pytest.approx(s_both / s_a)
        assert r.support == pytest.approx(s_both / n)
        assert r.lift == pytest.approx((s_both / s_a) / (s_b / n))


def test_implication_is_top_rule():
    T = db_with_implication()
    res = apriori(T, min_support=10)
    rules = generate_rules(res, min_confidence=0.8)
    assert rules, "expected at least the 0=>1 rule"
    top = rules[0]
    assert top.antecedent == (0,) and top.consequent == (1,)
    assert top.confidence > 0.9


def test_min_confidence_filters():
    T = db_with_implication()
    res = apriori(T, min_support=10)
    for thresh in (0.2, 0.5, 0.9):
        for r in generate_rules(res, min_confidence=thresh):
            assert r.confidence >= thresh


def test_independent_items_have_lift_near_one():
    T = db_with_implication(n=4000)
    res = apriori(T, min_support=20)
    rules = generate_rules(res, min_confidence=0.0)
    for r in rules:
        if set(r.antecedent) | set(r.consequent) == {2, 3}:
            assert r.lift == pytest.approx(1.0, abs=0.35)
