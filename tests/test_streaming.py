"""Streaming plane: sliding-window delta algebra, incremental-vs-batch
parity (hypothesis), re-validation triggers, refresh semantics, and the
ledger accounting contract shared with the other planes."""
import numpy as np
import pytest

from repro.core.itemsets import itemsets_to_bitmap
from repro.data.baskets import (BasketConfig, generate_baskets,
                                stationary_baskets)
from repro.kernels.support_count.ref import support_count_ref
from repro.pipeline import MarketBasketPipeline
from repro.streaming import (SlidingWindow, StreamingConfig, StreamingMiner,
                             TransactionStream)


def small_cfg(**kw):
    base = dict(window=256, batch_size=64, min_support=0.05,
                min_confidence=0.5, n_tiles=4, data_plane="ref",
                power="none")
    base.update(kw)
    return StreamingConfig(**base)


# ---------------------------------------------------------------------------
# sources: TransactionStream + SlidingWindow
# ---------------------------------------------------------------------------

def test_stream_batches_cover_corpus_in_order():
    T = generate_baskets(BasketConfig(n_tx=100, n_items=16, seed=0))
    s = TransactionStream(T, 32)
    batches = list(s)
    assert [len(b) for b in batches] == [32, 32, 32, 4]
    assert s.n_batches == 4
    np.testing.assert_array_equal(np.concatenate(batches), T)
    assert len(s.take(2)) == 2
    with pytest.raises(ValueError):
        TransactionStream(T, 0)
    with pytest.raises(ValueError):
        TransactionStream(np.array([[0, 2]]), 1)    # not 0/1


def test_window_push_returns_exact_slabs():
    w = SlidingWindow(4, 8)
    a1, e1 = w.push(np.eye(3, 8, dtype=np.uint8))
    assert a1.shape == (3, 128) and e1.shape == (0, 128)
    assert w.n == 3 and not w.full
    # second push overflows by 2: the two oldest rows evict
    a2, e2 = w.push(np.ones((3, 8), dtype=np.uint8))
    assert w.n == 4 and w.full
    np.testing.assert_array_equal(e2[:, :8], np.eye(3, 8, dtype=np.uint8)[:2])
    # arrival order preserved: eye row 2, then the three all-ones rows
    np.testing.assert_array_equal(
        w.rows_raw(),
        np.vstack([np.eye(3, 8, dtype=np.uint8)[2:],
                   np.ones((3, 8), dtype=np.uint8)]))


def test_window_batch_larger_than_capacity_stays_exact():
    """Rows that arrive and evict in one push must cancel in the delta."""
    rng = np.random.default_rng(0)
    w = SlidingWindow(4, 8)
    w.push(rng.integers(0, 2, size=(2, 8)).astype(np.uint8))
    old_sum = w.rows().sum(axis=0, dtype=np.int64)
    big = rng.integers(0, 2, size=(7, 8)).astype(np.uint8)
    arrived, evicted = w.push(big)
    assert arrived.shape[0] == 7 and evicted.shape[0] == 5
    np.testing.assert_array_equal(w.rows_raw(), big[-4:])
    # delta algebra: sum(window) == old sum + arrived - evicted
    np.testing.assert_array_equal(
        w.rows().sum(axis=0, dtype=np.int64),
        old_sum + arrived.sum(axis=0, dtype=np.int64)
        - evicted.sum(axis=0, dtype=np.int64))


def test_window_rows_do_not_alias_caller_buffer():
    """With n_items already lane-aligned, pad_items is a no-op — the window
    must still own its rows, or a caller reusing one buffer across pushes
    silently rewrites history."""
    buf = np.zeros((2, 128), dtype=np.uint8)     # 128 = no padding path
    buf[:, 0] = 1
    w = SlidingWindow(8, 128)
    w.push(buf)
    buf[:, :] = 0
    buf[:, 5] = 1                                # caller reuses the buffer
    w.push(buf)
    rows = w.rows_raw()
    assert rows[:2, 0].all() and not rows[:2, 5].any()   # history intact
    assert rows[2:, 5].all() and not rows[2:, 0].any()


# ---------------------------------------------------------------------------
# delta counters stay exact without re-validation
# ---------------------------------------------------------------------------

def test_delta_counters_match_full_recount_between_validations():
    T = stationary_baskets(1024, 32, n_patterns=4, seed=5)
    cfg = small_cfg(min_support=0.15)
    miner = StreamingMiner(32, config=cfg)
    for batch in TransactionStream(T, cfg.batch_size):
        miner.process_batch(batch)
        W = miner.window.rows()
        if miner._tracked:
            C = itemsets_to_bitmap(miner._tracked,
                                   miner.window.n_items_padded)
            want = np.asarray(support_count_ref(W, C), dtype=np.int64)
            np.testing.assert_array_equal(miner._tracked_supp, want)
        np.testing.assert_array_equal(miner._item_counts,
                                      W.sum(axis=0, dtype=np.int64))
    # the stationary stream settles: the tail of the run is delta-only
    assert not miner._batches[-1].revalidated


def test_stationary_stream_stops_revalidating():
    T = stationary_baskets(1536, 32, n_patterns=4, seed=9)
    cfg = small_cfg(min_support=0.15)
    miner = StreamingMiner(32, config=cfg)
    report = miner.run(TransactionStream(T, cfg.batch_size))
    warm = cfg.window // cfg.batch_size
    tail = report.batches[warm + 1:]
    assert tail and not any(b.revalidated for b in tail)
    # parity still holds at the end of the delta-only tail
    pipe = MarketBasketPipeline(config=cfg.pipeline_config()).run(
        miner.window.rows_raw())
    assert miner.supports == pipe.supports
    assert miner.rules == pipe.rules


def test_boundary_crossing_triggers_revalidation():
    """Flip the stream distribution mid-run: the lattice must go stale and
    re-validate, and the state must still match a one-shot mine."""
    A = stationary_baskets(512, 32, n_patterns=4, seed=1)
    B = stationary_baskets(512, 32, n_patterns=4, seed=2)   # different patterns
    cfg = small_cfg(min_support=0.15)
    miner = StreamingMiner(32, config=cfg)
    for batch in TransactionStream(A, cfg.batch_size):
        miner.process_batch(batch)
    before = len(miner._batches)
    for batch in TransactionStream(B, cfg.batch_size):
        miner.process_batch(batch)
    assert any(b.revalidated for b in miner._batches[before:])
    pipe = MarketBasketPipeline(config=cfg.pipeline_config()).run(
        miner.window.rows_raw())
    assert miner.supports == pipe.supports and miner.rules == pipe.rules


def test_revalidate_every_forces_periodic_full_pass():
    T = stationary_baskets(1024, 32, n_patterns=4, seed=5)
    cfg = small_cfg(min_support=0.15, revalidate_every=2)
    miner = StreamingMiner(32, config=cfg)
    report = miner.run(TransactionStream(T, cfg.batch_size))
    forced = [b.revalidated for b in report.batches if (b.idx + 1) % 2 == 0]
    assert forced and all(forced)


# ---------------------------------------------------------------------------
# refresh semantics
# ---------------------------------------------------------------------------

def test_refresh_every_batches_rule_regeneration_and_flush_closes_gap():
    T = stationary_baskets(1024, 32, n_patterns=4, seed=5)
    cfg = small_cfg(min_support=0.15, refresh_every=4)
    miner = StreamingMiner(32, config=cfg)
    for batch in TransactionStream(T, cfg.batch_size):
        miner.process_batch(batch)
    refreshes = [b for b in miner._batches
                 if b.rules_refreshed and not b.revalidated]
    # only every 4th batch refreshed on the delta path
    assert all(b.idx % 4 == 0 for b in refreshes)
    # rules may be stale now; flush must restore exact parity
    miner.flush()
    pipe = MarketBasketPipeline(config=cfg.pipeline_config()).run(
        miner.window.rows_raw())
    assert miner.rules == pipe.rules


def test_unchanged_supports_skip_rule_regeneration():
    """Pushing and evicting identical rows leaves supports untouched: the
    rules phase must not run again (no-op refresh)."""
    row = np.zeros((1, 8), dtype=np.uint8)
    row[0, :3] = 1
    cfg = StreamingConfig(window=4, batch_size=1, min_support=0.5,
                          min_confidence=0.5, n_tiles=1, data_plane="ref",
                          power="none")
    miner = StreamingMiner(8, config=cfg)
    for _ in range(8):                      # window cycles identical rows
        rep = miner.process_batch(row)
    assert not rep.rules_refreshed          # supports never moved
    assert miner.index is not None
    v = miner.index.version
    miner.flush()
    assert miner.index.version == v         # flush is a no-op too


def test_index_version_monotone_and_engine_hot_swap():
    from repro.serving import RecommendationEngine, RuleIndex, ServingConfig
    T = generate_baskets(BasketConfig(n_tx=768, n_items=24, seed=4))
    cfg = small_cfg(window=128, batch_size=64, min_support=0.08)
    engine = RecommendationEngine(
        RuleIndex.build([], 24),
        config=ServingConfig(k=3, data_plane="ref"))
    miner = StreamingMiner(24, config=cfg, engine=engine)
    versions = []
    for batch in TransactionStream(T, cfg.batch_size):
        rep = miner.process_batch(batch)
        versions.append(engine.index.version)
        assert engine.index is miner.index   # the swap is the same object
    assert versions == sorted(versions)      # monotone non-decreasing
    assert versions[-1] > 0                  # the stream did refresh


# ---------------------------------------------------------------------------
# accounting: the streaming plane speaks the shared ledger dialect
# ---------------------------------------------------------------------------

def test_ledger_slice_backs_report_totals():
    T = stationary_baskets(768, 32, n_patterns=4, seed=5)
    cfg = small_cfg(min_support=0.15, power="cpu")
    miner = StreamingMiner(32, config=cfg)
    report = miner.run(TransactionStream(T, cfg.batch_size))
    assert report.ledger is not None and report.ledger.n_phases > 0
    # every batch's phase count sums to the ledger slice: one PhaseRecord
    # per phase, none lost, none double-counted
    assert sum(b.n_phases for b in report.batches) == report.ledger.n_phases
    assert report.total_energy_j == pytest.approx(
        report.ledger.total_energy_j)
    assert report.total_time_s == pytest.approx(report.ledger.total_time_s)
    assert {p.kind for p in report.ledger.phases} <= {"serial", "map"}
    # take_report drained the live ledger (long-lived miner, no leak)
    assert miner.runtime.ledger.n_phases == 0
    assert "StreamingMiner" in report.summary()


def test_policy_knob_reaches_every_phase():
    T = stationary_baskets(512, 32, n_patterns=4, seed=5)
    cfg = small_cfg(min_support=0.15, policy="dynamic", power="cpu")
    miner = StreamingMiner(32, config=cfg)
    report = miner.run(TransactionStream(T, cfg.batch_size))
    assert report.policy == "dynamic"
    assert all(p.policy == "dynamic" for p in report.ledger.phases)


# The incremental-vs-batch hypothesis property tests live in
# tests/test_streaming_props.py behind the established module-top
# ``pytest.importorskip("hypothesis")`` guard, so this module's unit
# tests run even where hypothesis is not installed.
