"""Shared scheduling runtime: policy planning/feedback, ledger accounting,
speculative-move application, serial-constraint surfacing, and the
closed-loop dynamic-vs-static comparison under an injected straggler."""
import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler, TaskSpec
from repro.runtime import (CostModelPolicy, DynamicPolicy, MeasuredPhase,
                           Runtime, StaticPolicy, resolve_policy)


def modeled_executor():
    """Executor that lets the runtime model busy seconds from the plan."""
    def execute(asg, costs):
        return MeasuredPhase(result="ok")
    return execute


def true_speed_executor(true_speeds):
    """Executor that measures walls under the *true* rates — the believed
    profile only drives planning.  Feeds work_done so DynamicPolicy's EWMA
    loop can learn the real speeds."""
    true_speeds = np.asarray(true_speeds, dtype=np.float64)

    def execute(asg, costs):
        load = np.array([costs[ts].sum() if ts else 0.0
                         for ts in asg.tiles_of])
        busy = load / true_speeds
        return MeasuredPhase(result=None, busy_s=busy,
                             makespan=float(busy.max()), work_done=load)
    return execute


# ---------------------------------------------------------------------------
# serial phases + constraint surfacing (satellite: no silent fallback)
# ---------------------------------------------------------------------------

def test_run_serial_records_energy_and_picks_best_core():
    profile = HeterogeneityProfile.paper()
    rt = Runtime(profile, power="cpu")
    val, rec = rt.run_serial("phase", cost=400.0, fn=lambda: 42)
    assert val == 42
    assert rec.device == 3 and rec.sim_time_s == pytest.approx(1.0)
    assert sorted(rec.gated) == [0, 1, 2]
    assert not rec.constraint_violated
    # energy: chosen core active for 1s, the rest gated for 1s
    pm = rt.power
    want = pm.p_active[3] * 1.0 + sum(pm.p_gated[d] for d in (0, 1, 2))
    assert rec.energy_j == pytest.approx(want)
    assert rt.ledger.phases == [rec]


def test_min_speed_violation_is_flagged_not_hidden():
    profile = HeterogeneityProfile.paper()          # fastest core: 400
    rt = Runtime(profile, power="none")
    _, ok = rt.run_serial("fits", cost=1.0, min_speed=300.0)
    assert ok.device == 3 and not ok.constraint_violated
    _, bad = rt.run_serial("too-demanding", cost=1.0, min_speed=1000.0)
    assert bad.device == 3                          # fastest fallback...
    assert bad.constraint_violated                  # ...but flagged
    assert len(rt.ledger.constraint_violations()) == 1
    # pinning below min_speed is a violation too
    sched = MBScheduler(profile)
    asg = sched.assign_serial(TaskSpec("pinned", 1.0, parallel=False,
                                       min_speed=100.0), device=0)
    assert asg.serial_device == 0 and asg.constraint_violated


# ---------------------------------------------------------------------------
# static map phases: accounting matches the power model exactly once
# ---------------------------------------------------------------------------

def test_static_phase_energy_matches_manual_pricing():
    profile = HeterogeneityProfile.paper()
    rt = Runtime(profile, policy="static", power="cpu")
    costs = np.full(16, 100.0)
    task = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=16)
    _, rec = rt.run_phase(task, modeled_executor(), tile_costs=costs)
    busy = np.asarray(rec.busy_s)
    want = rt.power.energy(busy, rec.sim_time_s,
                           gated=[d for d in range(4) if busy[d] == 0.0],
                           switches=rec.switches + rec.reissued)
    assert rec.energy_j == pytest.approx(want)
    assert rec.policy == "static" and rec.kind == "map"
    assert sum(rec.tiles_done) == 16
    assert rt.ledger.total_energy_j == pytest.approx(rec.energy_j)


def test_pinned_assignment_gates_zero_cost_ranks():
    profile = HeterogeneityProfile.homogeneous(4, 100.0)
    rt = Runtime(profile, power="cpu")
    costs = np.array([100.0, 0.0, 100.0, 100.0])    # rank 1: dead/empty
    task = TaskSpec("pinned", 300.0, parallel=True, n_tiles=4)
    _, rec = rt.run_phase(task, modeled_executor(), tile_costs=costs,
                          assignment=rt.pinned_assignment(costs))
    assert rec.busy_s[1] == 0.0 and 1 in rec.gated
    assert rec.energy_j > 0
    assert rec.tiles_done == [1, 0, 1, 1]


# ---------------------------------------------------------------------------
# dynamic policy: the closed loop (EWMA feedback + speculation)
# ---------------------------------------------------------------------------

def _run_phases(policy, n_phases, believed, true_speeds, costs):
    rt = Runtime(believed.copy(), policy=policy, split="lpt", power="cpu")
    execute = true_speed_executor(true_speeds)
    total = 0.0
    for i in range(n_phases):
        task = TaskSpec("bench", float(costs.sum()), parallel=True,
                        n_tiles=len(costs))
        _, rec = rt.run_phase(task, execute, tile_costs=costs)
        total += rec.sim_time_s
    return total, rt


def test_dynamic_beats_static_under_injected_straggler():
    believed = HeterogeneityProfile(np.full(4, 100.0))
    true_speeds = np.array([20.0, 100.0, 100.0, 100.0])  # core 0 straggles
    rng = np.random.default_rng(0)
    costs = rng.uniform(50.0, 150.0, 64)
    t_static, _ = _run_phases("static", 6, believed, true_speeds, costs)
    t_dynamic, rt = _run_phases("dynamic", 6, believed, true_speeds, costs)
    assert t_dynamic < t_static * 0.8
    # the EWMA loop learned the straggler's true rate
    assert rt.profile.speeds[0] < 40.0
    assert rt.profile.speeds[1] == pytest.approx(100.0)


def test_dynamic_speculation_reissues_straggler_tiles():
    """equal split on the paper's cores: the 80-core lags the planned
    checkpoint, so its tail tiles re-issue to already-finished cores."""
    profile = HeterogeneityProfile.paper()
    rt_s = Runtime(profile.copy(), policy="static", split="equal",
                   power="cpu")
    rt_d = Runtime(profile.copy(), policy="dynamic", split="equal",
                   power="cpu")
    costs = np.full(32, 100.0)
    task = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=32)
    _, rec_s = rt_s.run_phase(task, modeled_executor(), tile_costs=costs)
    _, rec_d = rt_d.run_phase(task, modeled_executor(), tile_costs=costs)
    assert rec_d.reissued > 0
    assert rec_d.sim_time_s < rec_s.sim_time_s
    # re-issues migrate work: no tile lost, none duplicated
    assert sum(rec_d.tiles_done) == 32
    # energy priced the migrations
    assert rec_d.switches + rec_d.reissued > 0


def test_dynamic_rebalance_counts_owner_changes_as_switches():
    believed = HeterogeneityProfile(np.full(4, 100.0))
    true_speeds = np.array([25.0, 100.0, 100.0, 100.0])
    _, rt = _run_phases("dynamic", 3, believed, true_speeds,
                        np.full(32, 100.0))
    led = rt.ledger
    # the corrected speeds moved tiles off the straggler in later phases
    assert led.total_switches > 0
    assert rt.scheduler.switches >= led.total_switches - led.total_reissued


# ---------------------------------------------------------------------------
# costmodel policy: roofline seeding instead of raw byte counts
# ---------------------------------------------------------------------------

def test_costmodel_seeds_from_tile_flops():
    profile = HeterogeneityProfile.paper()
    policy = CostModelPolicy(peak_flops=1e12, hbm_bw=1e9)
    rt = Runtime(profile, policy=policy, power="none")
    bytes_ = np.full(8, 1e6)
    # tile 0 is violently compute-bound; the rest are memory-bound
    flops = np.array([1e12] + [1.0] * 7)
    seeded = policy.tile_costs(rt, None, bytes_, flops)
    assert seeded.sum() == pytest.approx(bytes_.sum())   # same work total
    assert seeded[0] > seeded[1] * 100                   # intensity skew
    # uniform intensity degenerates to the byte seeding
    flat = policy.tile_costs(rt, None, bytes_, bytes_ * 2.0)
    np.testing.assert_allclose(flat, bytes_)


def test_costmodel_phase_assignment_differs_from_static():
    profile = HeterogeneityProfile.paper()
    bytes_ = np.full(8, 1e6)
    flops = np.array([1e12] + [1.0] * 7)
    task = TaskSpec("t", float(bytes_.sum()), parallel=True, n_tiles=8)
    rt_s = Runtime(profile, policy="static", power="none")
    rt_c = Runtime(profile, policy=CostModelPolicy(peak_flops=1e12,
                                                   hbm_bw=1e9), power="none")
    seen = {}
    for name, rt in (("static", rt_s), ("costmodel", rt_c)):
        def execute(asg, costs):
            return MeasuredPhase(result=asg)
        asg, rec = rt.run_phase(task, execute, tile_costs=bytes_,
                                tile_flops=flops)
        seen[name] = asg
        assert sorted(t for ts in asg.tiles_of for t in ts) == list(range(8))
    # the compute-bound tile dominates under costmodel: it lands alone on
    # the fastest core, which a byte-uniform static plan never does
    owner = {t: d for d, ts in enumerate(seen["costmodel"].tiles_of)
             for t in ts}
    assert owner[0] == 3
    assert seen["costmodel"].tiles_of != seen["static"].tiles_of


def test_costmodel_from_hlo_derives_intensity():
    hlo = """
HloModule m

ENTRY %main (p0: f32[128,128], p1: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128] parameter(0)
  %p1 = f32[128,128] parameter(1)
  ROOT %dot = f32[128,128] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    policy = CostModelPolicy.from_hlo(hlo)
    # 2*128^3 flops over (result + 2 operand) f32[128,128] buffers
    want = (2.0 * 128 ** 3) / (3 * 128 * 128 * 4)
    assert policy.flops_per_byte == pytest.approx(want)


# ---------------------------------------------------------------------------
# apply_moves (satellite): speculation must mutate the assignment
# ---------------------------------------------------------------------------

def test_apply_moves_rehomes_tiles_and_stops_repeat_reissue():
    profile = HeterogeneityProfile.homogeneous(4, 100.0)
    sched = MBScheduler(profile, policy="equal")
    costs = np.full(16, 10.0)
    task = TaskSpec("t", 160.0, parallel=True, n_tiles=16)
    asg = sched.assign_parallel(task, costs)
    progress = np.array([0.1, 1.0, 1.0, 1.0])       # device 0 straggles
    moves = sched.speculate(asg, progress)
    assert moves
    first = {t for t, _ in moves}
    applied = sched.apply_moves(asg, moves, costs)
    # exact partition: nothing lost, nothing duplicated
    assert sorted(t for ts in applied.tiles_of for t in ts) == list(range(16))
    # every move changed the owner
    before, after = asg.owner_of(), applied.owner_of()
    assert sum(1 for t in after if after[t] != before[t]) == len(moves)
    # the bug this satellite fixes: a second speculation must not re-issue
    # the same tiles (they left the straggler's queue)
    again = sched.speculate(applied, progress)
    assert first.isdisjoint({t for t, _ in again})


def test_apply_moves_rejects_unassigned_tiles():
    profile = HeterogeneityProfile.homogeneous(2, 1.0)
    sched = MBScheduler(profile)
    asg = sched.assign_parallel(TaskSpec("t", 2.0, parallel=True, n_tiles=2),
                                np.ones(2))
    with pytest.raises(ValueError):
        sched.apply_moves(asg, [(99, 0)], np.ones(2))


# ---------------------------------------------------------------------------
# ledger + resolve helpers
# ---------------------------------------------------------------------------

def test_ledger_slices_isolate_runs():
    profile = HeterogeneityProfile.paper()
    rt = Runtime(profile, power="cpu")
    rt.run_serial("a", cost=100.0)
    mark = rt.ledger.mark()
    _, rec = rt.run_serial("b", cost=100.0)
    run2 = rt.ledger.since(mark)
    assert run2.n_phases == 1 and run2.phases[0] is rec
    assert rt.ledger.n_phases == 2
    assert "phases" in rt.ledger.summary()
    # take_since hands the slice to the run report AND compacts the live
    # ledger, so long-lived planes don't accumulate records forever
    taken = rt.ledger.take_since(mark)
    assert taken.n_phases == 1 and taken.phases[0] is rec
    assert rt.ledger.n_phases == mark


def test_serving_engine_ledger_does_not_grow_across_calls():
    from repro.data.baskets import BasketConfig, generate_baskets
    from repro.pipeline import MarketBasketPipeline, PipelineConfig
    from repro.serving import (Query, RecommendationEngine, RuleIndex,
                               ServingConfig)
    T = generate_baskets(BasketConfig(n_tx=400, n_items=24, seed=2))
    res = MarketBasketPipeline(
        config=PipelineConfig(min_support=0.05, min_confidence=0.5,
                              n_tiles=4)).run(T)
    engine = RecommendationEngine(
        RuleIndex.build(res.rules, T.shape[1]),
        config=ServingConfig(k=3, batch_buckets=(8,), data_plane="ref",
                             cache_size=0))
    queries = [Query.of(list(np.nonzero(row)[0])) for row in T[:16]]
    _, rep1 = engine.serve(queries)
    _, rep2 = engine.serve(queries)
    assert rep1.ledger.n_phases > 0 and rep2.ledger.n_phases > 0
    # each call took its slice; nothing is retained in the live ledger
    assert engine.runtime.ledger.n_phases == 0


def test_resolve_policy_names_and_errors():
    assert isinstance(resolve_policy("static"), StaticPolicy)
    assert isinstance(resolve_policy("dynamic"), DynamicPolicy)
    assert isinstance(resolve_policy(None), StaticPolicy)
    inst = DynamicPolicy()
    assert resolve_policy(inst) is inst
    with pytest.raises(ValueError):
        resolve_policy("nope")
    with pytest.raises(ValueError):
        Runtime(HeterogeneityProfile.paper(), power="warp-drive")


def test_planes_share_report_semantics():
    """The two simulated planes expose the same ledger-backed totals."""
    from repro.data.baskets import BasketConfig, generate_baskets
    from repro.pipeline import MarketBasketPipeline, PipelineConfig
    T = generate_baskets(BasketConfig(n_tx=256, n_items=24, seed=3))
    res = MarketBasketPipeline(
        config=PipelineConfig(min_support=0.05, n_tiles=4,
                              policy="dynamic")).run(T)
    rep = res.report
    assert rep.policy == "dynamic" and rep.split == "lpt"
    assert rep.ledger is not None
    assert rep.total_energy_j == pytest.approx(rep.ledger.total_energy_j)
    assert rep.total_time_s == pytest.approx(rep.ledger.total_time_s)
    # every phase in the ledger is either a serial or a map record
    assert {p.kind for p in rep.ledger.phases} <= {"serial", "map"}
    # two runs on one pipeline must not bleed into each other's ledger
    res2 = MarketBasketPipeline(
        config=PipelineConfig(min_support=0.05, n_tiles=4)).run(T)
    assert res2.report.ledger.n_phases == len(res2.report.ledger.phases)
