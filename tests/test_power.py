"""Power/core-switching model (paper §VI claims as invariants)."""
import numpy as np
import pytest

from repro.core.hetero import HeterogeneityProfile
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler, TaskSpec


def test_gating_reduces_energy_for_serial_tasks():
    """Paper: single-threaded task on the best core, others switched off."""
    profile = HeterogeneityProfile.paper()
    pm = PowerModel.cpu(profile)
    sched = MBScheduler(profile)
    asg = sched.assign_serial(TaskSpec("s", 100.0, parallel=False))
    busy = np.zeros(4)
    busy[asg.serial_device] = asg.makespan
    e_gated = pm.energy(busy, asg.makespan, gated=asg.gated)
    e_idle = pm.energy(busy, asg.makespan, gated=[])
    assert e_gated < e_idle


def test_switch_cost_charged():
    profile = HeterogeneityProfile.paper()
    pm = PowerModel.cpu(profile)
    busy = np.ones(4)
    e0 = pm.energy(busy, 1.0, switches=0)
    e5 = pm.energy(busy, 1.0, switches=5)
    assert e5 == pytest.approx(e0 + 5 * pm.switch_joules)


def test_heterogeneous_beats_homogeneous_energy_for_same_work():
    """Paper's core claim: the 4-core hetero system finishes faster, so
    (with idle power non-zero) it also burns less total energy than an
    equal-split schedule on the same hardware."""
    profile = HeterogeneityProfile.paper()
    pm = PowerModel.cpu(profile)
    costs = np.full(80, 10.0)
    task = TaskSpec("t", 800.0, parallel=True, n_tiles=80)
    a_prop = MBScheduler(profile, "proportional").assign_parallel(task, costs)
    a_eq = MBScheduler(profile, "equal").assign_parallel(task, costs)
    e_prop = pm.energy_of(a_prop, costs, profile)
    e_eq = pm.energy_of(a_eq, costs, profile)
    assert a_prop.makespan < a_eq.makespan
    assert e_prop < e_eq


def test_tpu_profile_sane():
    pm = PowerModel.tpu_v5e(256)
    assert pm.p_active[0] > pm.p_idle[0] > pm.p_gated[0]
