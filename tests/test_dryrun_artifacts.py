"""Guard the dry-run deliverable: every produced artifact is schema-complete,
every non-skipped cell compiled, and the cell matrix covers the task spec."""
import glob
import json
import os

import pytest

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

ARCHS = ["granite-3-8b", "minitron-8b", "mistral-nemo-12b", "gemma3-1b",
         "dbrx-132b", "deepseek-v2-236b", "hymba-1.5b", "musicgen-large",
         "rwkv6-7b", "internvl2-26b"]
LONG_OK = {"gemma3-1b", "hymba-1.5b", "rwkv6-7b"}


def _load():
    recs = {}
    for p in glob.glob(os.path.join(OUT, "*__tuned.json")):
        with open(p) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r.get("mesh_mode", "?"))] = r
    return recs


pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(OUT, "*__tuned.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun)")


def test_full_cell_matrix_present():
    recs = _load()
    for arch in ARCHS:
        for mesh in ("pod", "multipod"):
            for shape in ("train_4k", "prefill_32k", "decode_32k"):
                assert (arch, shape, mesh) in recs, (arch, shape, mesh)
            assert (arch, "long_500k", mesh) in recs


def test_all_applicable_cells_compiled():
    for key, r in _load().items():
        if r.get("skipped"):
            assert key[0] not in LONG_OK or key[1] != "long_500k", key
            continue
        assert r.get("ok"), (key, r.get("error", "")[:200])


def test_long_context_policy_matches_design():
    recs = _load()
    for arch in ARCHS:
        r = recs[(arch, "long_500k", "pod")]
        if arch in LONG_OK:
            assert r.get("ok"), arch
        else:
            assert r.get("skipped"), arch


def test_roofline_terms_well_formed():
    for key, r in _load().items():
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        for term in ("compute_s", "memory_s", "collective_s"):
            assert rl[term] >= 0, (key, term)
        assert rl["dominant"] in ("compute", "memory", "collective")
        assert 0 <= rl["roofline_fraction"] <= 1.5, key
        assert r["cost"]["flops"] > 0, key
        assert r["memory"]["peak_estimate_bytes"] > 0, key


def test_train_cells_report_collectives():
    for key, r in _load().items():
        if r.get("ok") and key[1] == "train_4k":
            assert r["collectives"]["total_bytes"] > 0, key
