"""B13 — device-resident round execution: pipelined (async tile dispatch,
donated slabs, on-device candgen, one d2h per counting round) vs the legacy
per-tile-sync baseline on the same corpus and tiling.

The corpus is pattern-rich (planted length-5 patterns) so the lattice runs
deep: the per-round costs the pipelined path eliminates — one readback per
tile, the host candidate join, the candidate-bitmap re-upload — repeat
across rounds while the counting matmuls stay identical, which is exactly
the regime the paper's round pipeline targets.  Measured like B6's plane
duel: warm both modes, interleave the reps so drift hits both equally,
report the median.  The baselines gate holds pipelined *strictly faster*;
tests/test_round_exec.py asserts the one-sync-per-round contract itself.

Rows carry the transfer ledger (h2d_bytes, d2h_bytes, syncs) so the CSV
shows the transfer asymmetry next to the wall-clock it buys.
"""
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.pipeline import MarketBasketPipeline, PipelineConfig

MODES = ("pipelined", "per_tile")


def run(csv_rows):
    profile = HeterogeneityProfile.paper()
    T = generate_baskets(BasketConfig(n_tx=4096, n_items=96, n_patterns=8,
                                      pattern_len=5, pattern_prob=0.35,
                                      seed=11))
    pipes, walls, reports = {}, {m: [] for m in MODES}, {}
    for mode in MODES:
        pipes[mode] = MarketBasketPipeline(
            profile, PipelineConfig(min_support=0.03, n_tiles=64,
                                    round_execution=mode))
        pipes[mode].run(T)                # warm the jit caches
    for _ in range(5):
        for mode, pipe in pipes.items():
            t0 = time.perf_counter()
            res = pipe.run(T)
            walls[mode].append((time.perf_counter() - t0) * 1e6)
            reports[mode] = res.report
    assert (reports["pipelined"].n_itemsets
            == reports["per_tile"].n_itemsets), \
        "round-execution modes diverged — bench refuses to time wrong answers"
    for mode in MODES:
        led = reports[mode].ledger
        csv_rows.append((f"round_exec_{mode}_wall",
                         float(np.median(walls[mode])),
                         reports[mode].n_itemsets, led.total_h2d_bytes,
                         led.total_d2h_bytes, led.total_syncs))
    # the transfer asymmetry the wall-clock gap comes from
    csv_rows.append(("round_exec_sync_reduction", 0.0,
                     reports["per_tile"].ledger.total_syncs
                     / max(1, reports["pipelined"].ledger.total_syncs)))
