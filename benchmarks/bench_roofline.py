"""B5 — §Roofline reader: aggregates results/dryrun/*.json into the
per-(arch × shape × mesh) three-term table.

derived = roofline fraction (useful model flops at peak / dominant term).
"""
import glob
import json
import os


def load_records(out_dir="results/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("ok"):
            recs.append(r)
    return recs


def run(csv_rows):
    recs = load_records()
    for r in recs:
        rl = r["roofline"]
        name = f"roofline_{r['arch']}_{r['shape']}_{r.get('mesh_mode','pod')}"
        bound_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        csv_rows.append((name, bound_s * 1e6, rl["roofline_fraction"]))


def table(out_dir="results/dryrun", profile=None):
    recs = load_records(out_dir)
    if profile:
        recs = [r for r in recs if r.get("profile") == profile]
    rows = []
    for r in recs:
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "mesh": r.get("mesh_mode", "?"), "profile": r.get("profile"),
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "useful": rl["useful_ratio"], "frac": rl["roofline_fraction"],
            "mem_gb": r["memory"]["peak_estimate_bytes"] / 1e9,
            "coll_bytes": r["collectives"]["total_bytes"],
            "flops_pd": r["cost"]["flops"],
        })
    return rows
