# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (DESIGN.md §7):

  B1 bench_apriori         — 3-step MapReduce Apriori scaling (paper §V)
  B2 bench_scheduler       — MB Scheduler vs equal split, 80/120/200/400 + pods
  B3 bench_power           — gating / switching energy (paper §VI)
  B4 bench_kernels         — Pallas hot-spots vs jnp oracle + TPU roofline
  B5 bench_roofline        — dry-run roofline table reader
  B6 bench_pipeline        — end-to-end MarketBasketPipeline (policies, scaling)
  B7 bench_serving         — online serving plane (QPS vs batch, cache, planes)
  B8 bench_sharded_mining  — distributed mining plane (shard-count scaling;
                             needs XLA_FLAGS=--xla_force_host_platform_
                             device_count=8 for the full curve)
  B9 bench_policies        — switching policies (static vs dynamic vs
                             costmodel under an injected straggler)
  B10 bench_streaming      — streaming plane (incremental delta-update vs
                             from-scratch re-mine per micro-batch;
                             rule-refresh-to-visible latency)
  B11 bench_algorithms     — apriori vs eclat vs auto cost-model routing
                             (dense + sparse-slab corpora; the
                             eclat-beats-apriori-on-dense and
                             auto-within-1.1x gates)
  B12 bench_async_serving  — continuous-batching async serving under
                             open-loop Poisson/bursty load (sustained QPS
                             + p99-under-load vs the closed-loop
                             per-request baseline; the async-strictly-
                             higher-QPS and p99-no-worse gates)
  B13 bench_round_exec     — device-resident round execution: pipelined
                             (async dispatch, donated slabs, on-device
                             candgen, one d2h per round) vs per-tile-sync
                             (the pipelined-strictly-faster gate)
  B14 bench_son            — SON out-of-core two-pass mining (wall vs
                             corpus size at a fixed partition_rows
                             memory budget; out-of-core overhead vs the
                             in-core pipeline on a fitting corpus — the
                             bounded-overhead gate)

Run: ``PYTHONPATH=src python -m benchmarks.run [--only B2]``

Regression gating: ``--check-baselines`` compares every measured
``us_per_call`` against ``benchmarks/baselines.json`` and fails when any
row regresses beyond ``--regression-factor`` (default 2.0×) — the CI perf
trajectory gate.  Refresh the baselines on the CI runner class with one
command: ``python -m benchmarks.run --update-baselines`` (optionally with
``--only ...``; un-run rows are preserved).  On noisy runners, repeat the
update a few times: it overwrites with the latest run, so keep the slowest
(largest) values if consecutive runs disagree — the checked-in file holds
max-over-runs values for exactly that reason.
"""
import argparse
import json
import os
import sys

from benchmarks import (bench_algorithms, bench_apriori,
                        bench_async_serving, bench_kernels, bench_pipeline,
                        bench_policies, bench_power, bench_roofline,
                        bench_round_exec, bench_scheduler, bench_serving,
                        bench_sharded_mining, bench_son, bench_streaming)

SUITES = {
    "B1": ("apriori", bench_apriori.run),
    "B2": ("scheduler", bench_scheduler.run),
    "B3": ("power", bench_power.run),
    "B4": ("kernels", bench_kernels.run),
    "B5": ("roofline", bench_roofline.run),
    "B6": ("pipeline", bench_pipeline.run),
    "B7": ("serving", bench_serving.run),
    "B8": ("sharded_mining", bench_sharded_mining.run),
    "B9": ("policies", bench_policies.run),
    "B10": ("streaming", bench_streaming.run),
    "B11": ("algorithms", bench_algorithms.run),
    "B12": ("async_serving", bench_async_serving.run),
    "B13": ("round_exec", bench_round_exec.run),
    "B14": ("son", bench_son.run),
}

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "baselines.json")


def _load_baselines(path):
    with open(path) as f:
        data = json.load(f)
    return data


def _update_baselines(path, rows):
    data = {"meta": {}, "us_per_call": {}}
    if os.path.exists(path):
        data = _load_baselines(path)
    data.setdefault("meta", {})
    data["meta"]["refresh"] = "python -m benchmarks.run --update-baselines"
    base = data.setdefault("us_per_call", {})
    for name, us, *_ in rows:
        if us > 0 and not name.endswith("_FAILED"):
            base[name] = round(us, 2)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# baselines updated: {path} ({len(base)} rows)", file=sys.stderr)


def _check_baselines(path, rows, factor, suite_names):
    data = _load_baselines(path)
    base = data.get("us_per_call", {})
    regressed, unknown = [], []
    measured = set()
    walls = {}
    for name, us, *_ in rows:
        if us <= 0 or name.endswith("_FAILED"):
            continue
        measured.add(name)
        walls[name] = us
        want = base.get(name)
        if want is None or want <= 0:
            unknown.append(name)
            continue
        if us > factor * want:
            regressed.append(f"{name}: {us:.2f}us > {factor:.1f}x "
                             f"baseline {want:.2f}us")
    # ordering rules: [fast, slow] pairs that must hold *this run* (the
    # Pallas-beats-ref gate) — checked whenever both rows were measured,
    # with no noise factor: "strictly faster" means what it says
    for fast, slow in data.get("rules", {}).get("strictly_faster", []):
        if fast in walls and slow in walls and walls[fast] >= walls[slow]:
            regressed.append(
                f"{fast}: {walls[fast]:.2f}us must be strictly faster "
                f"than {slow}: {walls[slow]:.2f}us")
    # auto_within rules: [row, [candidates...], factor] — a router row may
    # cost at most factor x the best candidate measured in the same run
    # (the algorithm auto-selection overhead gate)
    for row, cands, limit in data.get("rules", {}).get("auto_within", []):
        have = [walls[c] for c in cands if c in walls]
        if row in walls and have and walls[row] > limit * min(have):
            regressed.append(
                f"{row}: {walls[row]:.2f}us exceeds {limit:.1f}x the best "
                f"explicit choice ({min(have):.2f}us)")
    # no_worse rules: [a, b] pairs that must hold a <= b in the same run —
    # like strictly_faster but with equality allowed (the async-p99-never-
    # worse-than-closed-loop gate, where both sides can saturate)
    for a, b in data.get("rules", {}).get("no_worse", []):
        if a in walls and b in walls and walls[a] > walls[b]:
            regressed.append(
                f"{a}: {walls[a]:.2f}us must be no worse than "
                f"{b}: {walls[b]:.2f}us")
    if unknown:
        print(f"# baseline has no entry for {len(unknown)} row(s) "
              f"(not gated): {', '.join(unknown)} — refresh with "
              "--update-baselines", file=sys.stderr)
    # a gated row that stopped being emitted must not pass silently — it
    # usually means a suite clamped/renamed and the gate lost coverage.
    # Only look at rows belonging to the suites that actually ran, so a
    # --only B8 leg is not spammed about the B6/B7 rows it never measures.
    prefixes = tuple(f"{n}_" for n in suite_names)
    stale = sorted(k for k in base
                   if k not in measured and k.startswith(prefixes))
    if stale:
        print(f"# {len(stale)} baseline row(s) not measured this run "
              f"(gate coverage lost if unexpected): {', '.join(stale)}",
              file=sys.stderr)
    return regressed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of suite ids")
    ap.add_argument("--update-baselines", nargs="?", const=DEFAULT_BASELINES,
                    default=None, metavar="PATH",
                    help="write measured us_per_call into the baseline file "
                         f"(default {DEFAULT_BASELINES})")
    ap.add_argument("--check-baselines", nargs="?", const=DEFAULT_BASELINES,
                    default=None, metavar="PATH",
                    help="fail if any row regresses past --regression-factor "
                         "x its baseline")
    ap.add_argument("--regression-factor", type=float, default=2.0)
    # strict parse: a typo'd --check-baselines must not silently disable
    # the CI regression gate
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    unknown = only - set(SUITES)
    if unknown:
        ap.error(f"unknown suite ids {sorted(unknown)} "
                 f"(known: {', '.join(sorted(SUITES))})")

    rows = []
    failed = []
    for sid, (name, fn) in SUITES.items():
        if sid not in only:
            continue
        try:
            fn(rows)
        except Exception as e:  # noqa: BLE001 — report, keep the harness alive
            rows.append((f"{name}_FAILED", 0.0, 0.0))
            failed.append(sid)
            print(f"# {sid} {name} failed: {e}", file=sys.stderr)
    # rows are (name, us, derived) or, for transfer-instrumented suites
    # (B6/B8/B13/B14), (name, us, derived, h2d_bytes, d2h_bytes, syncs); the
    # CSV always carries the transfer columns (zeros when unmeasured)
    print("name,us_per_call,derived,h2d_bytes,d2h_bytes,syncs")
    for row in rows:
        name, us, derived = row[:3]
        h2d, d2h, syncs = row[3:] if len(row) > 3 else (0, 0, 0)
        print(f"{name},{us:.2f},{derived:.4f},{h2d},{d2h},{syncs}")

    if args.update_baselines:
        _update_baselines(args.update_baselines, rows)
    regressions = []
    if args.check_baselines:
        regressions = _check_baselines(args.check_baselines, rows,
                                       args.regression_factor,
                                       [SUITES[s][0] for s in only])
        for r in regressions:
            print(f"# REGRESSION {r}", file=sys.stderr)
        if not regressions:
            print("# baseline check OK", file=sys.stderr)

    if failed:   # every suite still reports, but CI must see the failure
        sys.exit(f"benchmark suites failed: {','.join(failed)}")
    if regressions:
        sys.exit(f"{len(regressions)} benchmark regression(s) past "
                 f"{args.regression_factor:.1f}x baseline")


if __name__ == "__main__":
    main()
