# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (DESIGN.md §7):

  B1 bench_apriori    — 3-step MapReduce Apriori scaling (paper §V)
  B2 bench_scheduler  — MB Scheduler vs equal split, 80/120/200/400 + pods
  B3 bench_power      — gating / switching energy (paper §VI)
  B4 bench_kernels    — Pallas hot-spots vs jnp oracle + TPU roofline
  B5 bench_roofline   — dry-run roofline table reader
  B6 bench_pipeline   — end-to-end MarketBasketPipeline (policies, scaling)
  B7 bench_serving    — online serving plane (QPS vs batch, cache, planes)

Run: ``PYTHONPATH=src python -m benchmarks.run [--only B2]``
"""
import argparse
import sys

from benchmarks import (bench_apriori, bench_kernels, bench_pipeline,
                        bench_power, bench_roofline, bench_scheduler,
                        bench_serving)

SUITES = {
    "B1": ("apriori", bench_apriori.run),
    "B2": ("scheduler", bench_scheduler.run),
    "B3": ("power", bench_power.run),
    "B4": ("kernels", bench_kernels.run),
    "B5": ("roofline", bench_roofline.run),
    "B6": ("pipeline", bench_pipeline.run),
    "B7": ("serving", bench_serving.run),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of suite ids")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    unknown = only - set(SUITES)
    if unknown:
        ap.error(f"unknown suite ids {sorted(unknown)} "
                 f"(known: {', '.join(sorted(SUITES))})")

    rows = []
    failed = []
    for sid, (name, fn) in SUITES.items():
        if sid not in only:
            continue
        try:
            fn(rows)
        except Exception as e:  # noqa: BLE001 — report, keep the harness alive
            rows.append((f"{name}_FAILED", 0.0, 0.0))
            failed.append(sid)
            print(f"# {sid} {name} failed: {e}", file=sys.stderr)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if failed:   # every suite still reports, but CI must see the failure
        sys.exit(f"benchmark suites failed: {','.join(failed)}")


if __name__ == "__main__":
    main()
