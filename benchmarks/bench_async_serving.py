"""B12 — continuous-batching async serving under open-loop load.

The claim under gate: at an offered rate past the per-request dispatch
capacity, the async plane (slot admission + AOT bucket ladder +
coalescing) sustains *strictly higher* QPS at *equal-or-lower* p99 than
the closed-loop per-request baseline — batching under load buys
throughput without giving back tail latency.

Both arms replay the same seeded Poisson/Zipf trace on the simulated
work-unit clock (deterministic, policy-sensitive — the number the
baselines pin), with host wall emitted alongside.  The closed arm is a
single-request bucket ladder: each arrival is dispatched alone, which is
what serving live traffic through ``serve()`` amounted to before the
open loop existed.  Gating (``baselines.json``):

  rules.strictly_faster  async_qps_inv < closed_qps_inv  (higher QPS)
  rules.no_worse         async_p99_us <= closed_p99_us   (tail no worse)

Emits ``name,us_per_call,derived`` rows; the ``*_qps_inv`` rows hold
1e6/QPS so lower is better like every other row.
"""
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.serving import (AsyncServer, RecommendationEngine, RuleIndex,
                           ServingConfig)

from benchmarks.load import bursty_arrivals, open_loop_trace

N_ITEMS = 64
N_REQUESTS = 256
# Offered rate: past the closed arm's per-request capacity (~3 QPS on the
# paper profile for this index) but within what bucket-64 batching can
# absorb — the regime where continuous batching is the difference between
# keeping up and diverging.
RATE_QPS = 6.0


def _mine_index(n_items=N_ITEMS):
    T = generate_baskets(BasketConfig(n_tx=2048, n_items=n_items, seed=1))
    res = MarketBasketPipeline(
        HeterogeneityProfile.paper(),
        PipelineConfig(min_support=0.03, n_tiles=8)).run(T)
    return RuleIndex.build(res.rules, n_items)


def _engine(index, buckets):
    # caches off in both arms: the comparison is batching, not memoization
    return RecommendationEngine(
        index, HeterogeneityProfile.paper(),
        ServingConfig(k=5, batch_buckets=buckets, data_plane="ref",
                      cache_size=0))


def run(csv_rows):
    index = _mine_index()
    queries, arrivals = open_loop_trace(N_REQUESTS, N_ITEMS, RATE_QPS,
                                        pattern="poisson", seed=5)
    span0 = float(arrivals[0])      # measure QPS over [first arrival, done]

    # -- closed-loop arm: per-request dispatch ------------------------------
    closed = _engine(index, (1,))
    closed.serve(queries[:8])                    # warm the jit caches
    t0 = time.perf_counter()
    _, crep = closed.serve(queries, arrivals)
    closed_wall_us = (time.perf_counter() - t0) * 1e6
    closed_qps = crep.n_queries / (crep.sim_time_s - span0)
    csv_rows.append(("async_serving_closed_qps_inv", 1e6 / closed_qps,
                     closed_qps))
    csv_rows.append(("async_serving_closed_p99_us",
                     crep.p99_latency_s * 1e6, crep.p50_latency_s))
    csv_rows.append(("async_serving_closed_wall",
                     closed_wall_us / crep.n_queries, closed_qps))

    # -- async arm: open loop on the AOT bucket ladder ----------------------
    server = AsyncServer(_engine(index, (1, 8, 64)))   # ctor warms the ladder
    t0 = time.perf_counter()
    for q, a in zip(queries, arrivals):
        server.submit(q, arrival_s=float(a))
    server.drain()
    async_wall_us = (time.perf_counter() - t0) * 1e6
    arep = server.take_report()
    assert arep.n_completed == N_REQUESTS
    csv_rows.append(("async_serving_async_qps_inv",
                     1e6 / arep.sustained_qps, arep.sustained_qps))
    csv_rows.append(("async_serving_async_p99_us",
                     arep.p99_latency_s * 1e6, arep.p50_latency_s))
    csv_rows.append(("async_serving_async_wall",
                     async_wall_us / arep.n_completed, arep.sustained_qps))

    # -- bursty traffic through the same ladder (coalescing absorbs the
    # bursts; derived = mean batch fill actually achieved) ------------------
    bursty = bursty_arrivals(N_REQUESTS, RATE_QPS, seed=9)
    server = AsyncServer(_engine(index, (1, 8, 64)))
    for q, a in zip(queries, bursty):
        server.submit(q, arrival_s=float(a))
    server.drain()
    brep = server.take_report()
    csv_rows.append(("async_serving_bursty_p99_us",
                     brep.p99_latency_s * 1e6, brep.batch_fill))

    # -- SLO governor under the same load: shed rate as derived; the p99 of
    # what *was* served must sit inside the budget once the EWMA settles ----
    slo_ms = 2000.0
    eng = RecommendationEngine(
        index, HeterogeneityProfile.paper(),
        ServingConfig(k=5, batch_buckets=(1, 8, 64), data_plane="ref",
                      cache_size=0, slo_ms=slo_ms))
    server = AsyncServer(eng)
    for q, a in zip(queries, arrivals):
        server.submit(q, arrival_s=float(a))
    server.drain()
    srep = server.take_report()
    csv_rows.append(("async_serving_slo_p99_us",
                     srep.p99_latency_s * 1e6, srep.shed_rate))
