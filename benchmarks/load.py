"""Open-loop load generator for the async serving benchmarks and tests.

Closed-loop measurement (send a batch, wait, send the next) can only ever
see the server keeping up — the arrival process adapts to the service
rate and queueing delay is invisible.  Open-loop load fixes the arrival
process independently of the server (the standard serving-benchmark
discipline), so sustained throughput and latency-under-load mean what
they say.  Everything here is seeded and deterministic.

  poisson_arrivals  — memoryless arrivals at a target rate (the steady
                      open-loop baseline)
  bursty_arrivals   — alternating burst/lull phases around the same mean
                      rate (what slot admission + bucket coalescing exist
                      to absorb)
  zipf_queries      — baskets over a Zipf-popular item universe (head
                      items repeat across baskets: the realistic cache /
                      coalescing mix, unlike uniform corpora)
  open_loop_trace   — the three composed: (queries, arrival_s) ready for
                      ``AsyncServer.submit`` or ``engine.serve``
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.serving import Query


def poisson_arrivals(n: int, rate_qps: float, seed: int = 0) -> np.ndarray:
    """n arrival instants with exponential inter-arrival gaps (Poisson
    process at ``rate_qps``), starting after the first gap."""
    if rate_qps <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def bursty_arrivals(n: int, rate_qps: float, burst_factor: float = 8.0,
                    burst_len: int = 16, seed: int = 0) -> np.ndarray:
    """Bursty open-loop arrivals with overall mean rate ``rate_qps``.

    Requests alternate between bursts of ``burst_len`` arriving at
    ``burst_factor`` x the mean rate and lulls slowed down so the overall
    mean stays at ``rate_qps`` — the burst and the lull trade the same
    time budget.  Exercises coalescing (bursts fill big buckets) and
    queue drain (lulls let the backlog clear).
    """
    if rate_qps <= 0:
        return np.zeros(n)
    if burst_factor <= 1.0:
        return poisson_arrivals(n, rate_qps, seed)
    rng = np.random.default_rng(seed)
    # mean gap g must satisfy: half the requests at g/f, half at g_lull,
    # with (g/f + g_lull)/2 == g  =>  g_lull = g(2 - 1/f)
    g = 1.0 / rate_qps
    gaps = np.empty(n)
    for i in range(n):
        in_burst = (i // burst_len) % 2 == 0
        mean = g / burst_factor if in_burst else g * (2.0 - 1.0 / burst_factor)
        gaps[i] = rng.exponential(mean)
    return np.cumsum(gaps)


def zipf_queries(n: int, n_items: int, alpha: float = 1.2,
                 mean_len: float = 3.0, seed: int = 0) -> List[Query]:
    """n basket queries over a Zipf(``alpha``) item popularity.

    Head items recur across baskets — the repeated-basket tail a result
    cache wins on and the realistic skew for coalesced batches.  Basket
    length is 1 + Poisson(mean_len - 1); items are drawn without
    replacement within a basket.
    """
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_items + 1, dtype=np.float64) ** alpha
    p /= p.sum()
    queries = []
    for _ in range(n):
        size = min(1 + rng.poisson(max(mean_len - 1.0, 0.0)), n_items)
        queries.append(Query.of(
            sorted(rng.choice(n_items, size=size, replace=False,
                              p=p).tolist())))
    return queries


def open_loop_trace(n: int, n_items: int, rate_qps: float,
                    pattern: str = "poisson", alpha: float = 1.2,
                    mean_len: float = 3.0, burst_factor: float = 8.0,
                    burst_len: int = 16, seed: int = 0
                    ) -> Tuple[List[Query], np.ndarray]:
    """(queries, arrival_s) for one open-loop run; ``pattern`` is
    ``poisson`` or ``bursty``."""
    if pattern == "poisson":
        arrivals = poisson_arrivals(n, rate_qps, seed=seed + 1)
    elif pattern == "bursty":
        arrivals = bursty_arrivals(n, rate_qps, burst_factor=burst_factor,
                                   burst_len=burst_len, seed=seed + 1)
    else:
        raise ValueError(f"unknown arrival pattern {pattern!r} "
                         f"(poisson | bursty)")
    return zipf_queries(n, n_items, alpha=alpha, mean_len=mean_len,
                        seed=seed), arrivals
