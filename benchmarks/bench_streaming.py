"""B10 — streaming plane: incremental delta-update vs from-scratch re-mine.

The claim the streaming plane exists for: once the window is warm and the
frequent-set lattice is stable, absorbing a micro-batch costs work
proportional to the *batch* (delta support counting on the arrive/evict
slabs) instead of the *window* (a full Apriori re-mine).  The stream is
``stationary_baskets`` — disjoint high-margin patterns — so no measured
batch triggers a re-validation; ``generate_baskets``-style threshold
churn is the re-validation path, which B10 deliberately excludes (it
would measure Apriori again, which B6 already does).

Rows (host wall, measured; the delta/re-mine pair runs on identical
windows and the final states are asserted bit-identical):

  streaming_delta_batch_wall    us per micro-batch, incremental path
                                (derived = re-validations in the span,
                                must be 0)
  streaming_remine_batch_wall   us per micro-batch, one-shot pipeline on
                                the same window (derived = speedup x)
  streaming_refresh_latency     us from rules regeneration to the index
                                being visible in the live engine
                                (derived = refreshes in the span)

Gate: the delta path must be strictly faster per batch than re-mining —
a regression here means the incremental plane lost its reason to exist.
"""
import time

import numpy as np

from repro.data.baskets import stationary_baskets
from repro.pipeline import MarketBasketPipeline
from repro.serving import RecommendationEngine, RuleIndex, ServingConfig
from repro.streaming import StreamingConfig, StreamingMiner, TransactionStream

WINDOW, BATCH, N_ITEMS, K = 2048, 128, 64, 8


def run(csv_rows):
    cfg = StreamingConfig(window=WINDOW, batch_size=BATCH, min_support=0.08,
                          min_confidence=0.6, n_tiles=8, data_plane="ref")
    T = stationary_baskets(WINDOW + (K + 4) * BATCH, N_ITEMS, seed=3)
    batches = list(TransactionStream(T, BATCH))

    engine = RecommendationEngine(
        RuleIndex.build([], N_ITEMS),
        config=ServingConfig(k=5, data_plane="ref"))
    miner = StreamingMiner(N_ITEMS, config=cfg, engine=engine)

    # warm: fill the window, settle the lattice, compile both data planes
    warm = WINDOW // BATCH + 2
    for b in batches[:warm]:
        miner.process_batch(b)
    MarketBasketPipeline(config=cfg.pipeline_config()).run(
        miner.window.rows_raw())

    delta_s, remine_s, refresh_s, revals = [], [], [], 0
    for b in batches[warm:warm + K]:
        rep = miner.process_batch(b)
        delta_s.append(rep.wall_s)
        revals += int(rep.revalidated)
        if rep.rules_refreshed:
            refresh_s.append(rep.refresh_latency_s)
        t0 = time.perf_counter()
        res = MarketBasketPipeline(config=cfg.pipeline_config()).run(
            miner.window.rows_raw())
        remine_s.append(time.perf_counter() - t0)
        # the comparison is only meaningful if both paths mined the same
        # thing — parity is the streaming plane's contract
        if miner.supports != res.supports or miner.rules != res.rules:
            raise AssertionError("streaming state diverged from the "
                                 "one-shot re-mine — delta path is broken")

    delta_us = float(np.mean(delta_s)) * 1e6
    remine_us = float(np.mean(remine_s)) * 1e6
    refresh_us = float(np.mean(refresh_s)) * 1e6 if refresh_s else 0.0
    csv_rows.append(("streaming_delta_batch_wall", delta_us, float(revals)))
    csv_rows.append(("streaming_remine_batch_wall", remine_us,
                     remine_us / max(delta_us, 1e-9)))
    csv_rows.append(("streaming_refresh_latency", refresh_us,
                     float(len(refresh_s))))
    if delta_us >= remine_us:
        raise AssertionError(
            f"delta update ({delta_us:.0f}us/batch) must beat from-scratch "
            f"re-mining ({remine_us:.0f}us/batch) on a stable window")
    if revals:
        raise AssertionError(
            f"{revals} re-validation(s) in the measured span — the "
            f"stationary stream should never destabilize the lattice")
