"""B8 — the distributed mining plane: support-count scaling over shard
counts 1/2/4/8, uniform vs heterogeneity-aware split.

Needs a multi-device mesh for the e2e rows — CI's multidevice leg runs it
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; with fewer
visible devices the sweep clamps (and says so on stderr rather than
silently shrinking coverage).

Rows:
  sharded_mining_s{n}_map_wall     wall us of ONE shard's support-count map
                                   program — the map phase's critical path
                                   on an n-rank mesh, where every rank runs
                                   its shard concurrently.  This is the
                                   scaling claim: it must fall monotonically
                                   as shards shrink 1 → 8.  (Forced host
                                   devices time-share this container's
                                   cores, so e2e wall cannot show true
                                   n-way parallelism; the per-shard program
                                   can, exactly as the simulator models it.)
  sharded_mining_s{n}_e2e_wall     full ShardedMiner run on the n-rank mesh
                                   (uniform profile), derived = speedup vs 1
  sharded_mining_s{n}_hetero_wall  e2e on the cycled 80/120/200/400 profile,
                                   derived = modeled makespan speedup of the
                                   ∝-speed split over an equal split on the
                                   same speeds
"""
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.kernels.support_count.ref import support_count_ref
from repro.pipeline import PipelineConfig

SHARD_COUNTS = (1, 2, 4, 8)
# min-of-batches timing: small shards finish in tens of ms, where scheduler
# noise on shared CI runners swamps a mean — the fastest batch is the stable
# estimator of true cost (what the regression gate compares across pushes)
REPS = 5
BATCHES = 4


def _timed_run(miner, T, runs=2):
    miner.run(T)                       # warm the compiled-program cache
    best, res = float("inf"), None
    for _ in range(runs):
        t0 = time.perf_counter()
        res = miner.run(T)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, res


def run(csv_rows):
    from repro.distributed.mining import (ShardedMiner, make_shard_mesh,
                                          mesh_profile, plan_shards,
                                          shard_bitmap)

    ndev = jax.local_device_count()
    counts = [c for c in SHARD_COUNTS if c <= ndev]
    if counts != list(SHARD_COUNTS):
        print(f"# B8: only {ndev} device(s) visible — e2e shard sweep "
              f"clamped to {counts} (run under XLA_FLAGS=--xla_force_host_"
              "platform_device_count=8 for the full curve)", file=sys.stderr)

    T = generate_baskets(BasketConfig(n_tx=32768, n_items=96, seed=1))
    Tp = np.pad(T, ((0, 0), (0, 128 - T.shape[1])))      # lane padding
    rng = np.random.default_rng(2)
    C = np.zeros((512, 128), dtype=np.uint8)             # k=2-shaped batch
    for i in range(len(C)):
        C[i, rng.choice(T.shape[1], size=2, replace=False)] = 1
    count = jax.jit(support_count_ref)

    # ---- map-phase critical path: one shard's program, per shard count --
    # (always the full 1/2/4/8 sweep: a single shard program needs no mesh)
    base_us = None
    for n in SHARD_COUNTS:
        prof = HeterogeneityProfile.homogeneous(n, 200.0)
        plan = plan_shards(prof, Tp.shape[0])
        shard = jnp.asarray(shard_bitmap(Tp, plan)[:plan.width])
        Cj = jnp.asarray(C)
        jax.block_until_ready(count(shard, Cj))          # warm per shape
        wall_us = float("inf")
        for _ in range(BATCHES):
            t0 = time.perf_counter()
            for _ in range(REPS):
                out = count(shard, Cj)
            jax.block_until_ready(out)
            wall_us = min(wall_us,
                          (time.perf_counter() - t0) / REPS * 1e6)
        base_us = base_us or wall_us
        csv_rows.append((f"sharded_mining_s{n}_map_wall", wall_us,
                         base_us / wall_us))

    # ---- e2e: the real sharded pipeline on an n-rank mesh ---------------
    cfg = PipelineConfig(min_support=0.02)
    base_us = None
    for n in counts:
        miner = ShardedMiner(
            mesh=make_shard_mesh(n),
            profile=HeterogeneityProfile.homogeneous(n, 200.0), config=cfg)
        wall_us, res = _timed_run(miner, T)
        base_us = base_us or wall_us
        led = res.report.ledger
        csv_rows.append((f"sharded_mining_s{n}_e2e_wall", wall_us,
                         base_us / wall_us, led.total_h2d_bytes,
                         led.total_d2h_bytes, led.total_syncs))

    # ---- heterogeneous split at max mesh size ---------------------------
    # wall time runs on equal silicon (forced host devices), so the
    # heterogeneity win lives in the *modeled* makespan: ∝-speed row split
    # vs an equal split on the same 80/120/200/400 speeds.
    n = counts[-1]
    profile = mesh_profile(n)
    miner = ShardedMiner(mesh=make_shard_mesh(n), profile=profile, config=cfg)
    wall_us, res = _timed_run(miner, T)
    hetero_modeled = res.report.map_time_s
    rows_equal = -(-T.shape[0] // n)               # equal split, ceil
    items_padded = -(-T.shape[1] // 128) * 128     # kernel lane padding
    n_map_rounds = sum(1 for r in res.report.rounds if r.n_tiles)
    equal_modeled = (n_map_rounds * rows_equal * items_padded
                     / float(profile.speeds.min()))
    led = res.report.ledger
    csv_rows.append((f"sharded_mining_s{n}_hetero_wall", wall_us,
                     equal_modeled / hetero_modeled, led.total_h2d_bytes,
                     led.total_d2h_bytes, led.total_syncs))
