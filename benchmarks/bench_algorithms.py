"""B11 — algorithm selection: Apriori vs Eclat vs the auto cost model.

The same mining job runs under all three ``PipelineConfig.algorithm``
values on a dense IBM-Quest bitmap, plus Eclat on a wide-universe sparse
corpus fed through the CSR slab (the vertical path packs tid-columns from
the slab directly — the dense bitmap is never built).  Measured like B6's
data-plane rows: warm every miner first, interleave the reps so clock
drift hits all arms equally, report the median.

Rows:
  algorithms_apriori_dense_wall   derived = n_itemsets
  algorithms_eclat_dense_wall     derived = n_itemsets
  algorithms_auto_dense_wall      derived = n_itemsets
  algorithms_eclat_sparse_wall    derived = n_itemsets
  algorithms_auto_pick_eclat      derived = 1.0 if auto chose eclat

Gates (baselines.json rules):
  strictly_faster [eclat_dense, apriori_dense] — the vertical plane must
      beat the horizontal one on the dense corpus, same run, no noise
      factor;
  auto_within [auto_dense, [apriori_dense, eclat_dense], 1.1] — the auto
      router may never cost more than 1.1x the best explicit choice (its
      overhead is one density scan + a cost-model evaluation).
"""
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets, sparse_baskets
from repro.data.sparse import SparseSlab
from repro.mining import make_miner
from repro.pipeline import PipelineConfig

REPS = 3


def _config(algorithm, min_support=0.02):
    return PipelineConfig(min_support=min_support, n_tiles=16,
                          algorithm=algorithm)


def run(csv_rows):
    profile = HeterogeneityProfile.paper()

    # dense corpus: all three algorithm values on identical data
    T = generate_baskets(BasketConfig(n_tx=8192, n_items=96, seed=3))
    miners, walls, itemsets = {}, {}, {}
    auto_choice = None
    for name in ("apriori", "eclat", "auto"):
        miner, choice = make_miner(T, profile=profile,
                                   config=_config(name))
        if name == "auto":
            auto_choice = choice
        miners[name] = miner
        miner.run(T)                      # warm the jit caches
        walls[name] = []
    for _ in range(REPS):
        for name, miner in miners.items():
            t0 = time.perf_counter()
            res = miner.run(T)
            walls[name].append((time.perf_counter() - t0) * 1e6)
            itemsets[name] = res.report.n_itemsets
    assert itemsets["apriori"] == itemsets["eclat"] == itemsets["auto"], \
        "algorithm backends disagree on the dense corpus"
    for name in ("apriori", "eclat", "auto"):
        csv_rows.append((f"algorithms_{name}_dense_wall",
                         float(np.median(walls[name])), itemsets[name]))
    csv_rows.append(("algorithms_auto_pick_eclat", 0.0,
                     1.0 if (auto_choice is not None and
                             auto_choice.algorithm == "eclat") else 0.0))

    # sparse corpus through the CSR slab: the Eclat path scatters packed
    # tid-columns straight out of the slab, never the dense bitmap
    slab = SparseSlab.from_baskets(
        sparse_baskets(4096, 512, seed=3, max_item_freq=0.03), n_items=512)
    miner, _ = make_miner(slab, profile=profile,
                          config=_config("eclat", min_support=0.01))
    miner.run(slab)
    sparse_walls = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        res = miner.run(slab)
        sparse_walls.append((time.perf_counter() - t0) * 1e6)
    csv_rows.append(("algorithms_eclat_sparse_wall",
                     float(np.median(sparse_walls)), res.report.n_itemsets))
