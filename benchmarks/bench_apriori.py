"""B1 — paper §V: 3-step MapReduce Apriori, scaling with DB size and tiles.

Emits ``name,us_per_call,derived`` CSV rows; derived = itemsets found.
"""
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.itemsets import apriori
from repro.core.mapreduce import SimulatedCluster
from repro.core.scheduler import MBScheduler
from repro.data.baskets import BasketConfig, generate_baskets, pad_items


def run(csv_rows):
    profile = HeterogeneityProfile.paper()
    for n_tx in (2048, 8192, 32768):
        T = pad_items(generate_baskets(BasketConfig(n_tx=n_tx, n_items=96, seed=1)))
        cluster = SimulatedCluster(profile, MBScheduler(profile, "lpt"))
        t0 = time.perf_counter()
        res = apriori(T, max(2, int(0.02 * n_tx)), cluster=cluster, n_tiles=32)
        wall = (time.perf_counter() - t0) * 1e6
        sim = sum(rep.makespan for _, rep in res.reports)
        csv_rows.append((f"apriori_ntx{n_tx}", wall, len(res.supports)))
        csv_rows.append((f"apriori_ntx{n_tx}_sim_makespan_us", sim * 1e6,
                         res.levels))
    # tile-count scaling at fixed size (parallelism sweep)
    T = pad_items(generate_baskets(BasketConfig(n_tx=8192, n_items=96, seed=1)))
    for tiles in (4, 16, 64):
        cluster = SimulatedCluster(profile, MBScheduler(profile, "lpt"))
        res = apriori(T, 164, cluster=cluster, n_tiles=tiles)
        sim = sum(rep.makespan for _, rep in res.reports)
        csv_rows.append((f"apriori_tiles{tiles}_sim_makespan_us", sim * 1e6,
                         len(res.supports)))
