"""B6 — the end-to-end MarketBasketPipeline: policy sweep, DB-size scaling,
and data-plane comparison on the paper's heterogeneous four-core system.

Emits ``name,us_per_call,derived`` CSV rows; derived varies per row
(itemsets, rules, simulated speedup, energy).  Wall rows carry the run's
transfer ledger (h2d_bytes, d2h_bytes, syncs) as extra columns.
"""
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.pipeline import MarketBasketPipeline, PipelineConfig


def run(csv_rows):
    profile = HeterogeneityProfile.paper()

    # policy sweep at fixed size: simulated makespan + energy per policy
    T = generate_baskets(BasketConfig(n_tx=8192, n_items=96, seed=1))
    sims = {}
    for split in ("equal", "proportional", "lpt"):
        pipe = MarketBasketPipeline(
            profile, PipelineConfig(min_support=0.02, n_tiles=32,
                                    split=split))
        t0 = time.perf_counter()
        res = pipe.run(T)
        wall_us = (time.perf_counter() - t0) * 1e6
        # map phases only: serial phases are policy-invariant, and this is
        # the ratio comparable to the paper's 2.50x analytic bound
        sims[split] = res.report.map_time_s
        led = res.report.ledger
        csv_rows.append((f"pipeline_{split}_wall", wall_us,
                         res.report.n_itemsets, led.total_h2d_bytes,
                         led.total_d2h_bytes, led.total_syncs))
        csv_rows.append((f"pipeline_{split}_sim_makespan_us",
                         res.report.total_time_s * 1e6,
                         res.report.total_energy_j))
    csv_rows.append(("pipeline_lpt_speedup_vs_equal", 0.0,
                     sims["equal"] / sims["lpt"]))

    # DB-size scaling under the MB Scheduler
    for n_tx in (2048, 8192, 32768):
        T = generate_baskets(BasketConfig(n_tx=n_tx, n_items=96, seed=1))
        pipe = MarketBasketPipeline(
            profile, PipelineConfig(min_support=0.02, n_tiles=32))
        t0 = time.perf_counter()
        res = pipe.run(T)
        wall_us = (time.perf_counter() - t0) * 1e6
        led = res.report.ledger
        csv_rows.append((f"pipeline_ntx{n_tx}_wall", wall_us,
                         res.report.n_rules, led.total_h2d_bytes,
                         led.total_d2h_bytes, led.total_syncs))

    # data plane: jitted ref vs autotuned Pallas (interpret off-TPU).  The
    # baselines hold pallas *strictly faster* than ref, so measure like the
    # tuner does: warm both, interleave the reps (drift hits both planes
    # equally), report the median
    T = generate_baskets(BasketConfig(n_tx=4096, n_items=128, seed=2))
    pipes, walls, reports = {}, {}, {}
    for plane in ("ref", "pallas"):
        pipes[plane] = MarketBasketPipeline(
            profile, PipelineConfig(min_support=0.02, n_tiles=16,
                                    data_plane=plane))
        pipes[plane].run(T)               # warm the jit caches
        walls[plane] = []
    for _ in range(3):
        for plane, pipe in pipes.items():
            t0 = time.perf_counter()
            res = pipe.run(T)
            walls[plane].append((time.perf_counter() - t0) * 1e6)
            reports[plane] = res.report
    for plane in ("ref", "pallas"):
        led = reports[plane].ledger
        csv_rows.append((f"pipeline_dataplane_{plane}_wall",
                         float(np.median(walls[plane])),
                         reports[plane].n_itemsets, led.total_h2d_bytes,
                         led.total_d2h_bytes, led.total_syncs))
