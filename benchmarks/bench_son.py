"""B14 — SON out-of-core two-pass mining.

Two claims, two row families:

* ``son_ntx{N}_wall`` — wall clock vs corpus size at a *fixed* device-
  memory budget (``partition_rows`` held constant, so the partition count
  grows with the corpus).  SON's work per partition is constant here, so
  the wall should scale ~linearly in N — the derived column carries the
  partition count, and the per-row transfer columns carry the ledger's
  h2d/d2h/sync totals so checkpoint + spill I/O stays visible.

* ``son_outofcore_wall`` vs ``son_incore_wall`` — the overhead of the
  two-pass plane on a corpus that *fits* in core, against the single-shot
  pipeline on the same data.  Gated in baselines.json with an
  ``auto_within`` rule: spill + two passes + boundary checkpoints may
  cost at most the configured factor over in-core — the price of crash
  safety, bounded.

Every timed SON run starts from a clean workdir (spill included), so the
measured wall is the full out-of-core protocol, not a warm-cache replay.
"""
import shutil
import tempfile
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.mining import SONConfig, SONMiner
from repro.pipeline import MarketBasketPipeline, PipelineConfig

PARTITION_ROWS = 1024
SIZES = (2048, 4096, 8192)
REPS = 3


def _config():
    return PipelineConfig(min_support=0.03, n_tiles=16)


def _son_run(T, workdir):
    miner = SONMiner(profile=HeterogeneityProfile.paper(), config=_config(),
                     son=SONConfig(workdir=workdir,
                                   partition_rows=PARTITION_ROWS))
    return miner.run(T)


def run(csv_rows):
    corpora = {n: generate_baskets(BasketConfig(n_tx=n, n_items=64, seed=13))
               for n in SIZES}
    root = tempfile.mkdtemp(prefix="bench-son-")
    try:
        # warm the jit caches once (kernel compiles are not SON's story)
        _son_run(corpora[SIZES[0]], f"{root}/warm")

        # ---- wall vs corpus size at fixed memory budget ----------------
        for n, T in corpora.items():
            walls, report = [], None
            for r in range(REPS):
                wd = f"{root}/n{n}-r{r}"
                t0 = time.perf_counter()
                res = _son_run(T, wd)
                walls.append((time.perf_counter() - t0) * 1e6)
                report = res.report
            led = report.ledger
            csv_rows.append((f"son_ntx{n}_wall", float(np.median(walls)),
                             report.n_partitions, led.total_h2d_bytes,
                             led.total_d2h_bytes, led.total_syncs))

        # ---- SON overhead vs in-core on a fitting corpus ---------------
        T = corpora[SIZES[0]]
        pipe = MarketBasketPipeline(HeterogeneityProfile.paper(), _config())
        pipe.run(T)                      # warm
        son_walls, in_walls = [], []
        son_res = in_res = None
        for r in range(REPS):
            t0 = time.perf_counter()
            son_res = _son_run(T, f"{root}/oc-r{r}")
            son_walls.append((time.perf_counter() - t0) * 1e6)
            t0 = time.perf_counter()
            in_res = pipe.run(T)
            in_walls.append((time.perf_counter() - t0) * 1e6)
        assert son_res.supports == in_res.supports, \
            "out-of-core diverged from in-core — bench refuses to time " \
            "wrong answers"
        csv_rows.append(("son_outofcore_wall", float(np.median(son_walls)),
                         son_res.report.n_partitions))
        csv_rows.append(("son_incore_wall", float(np.median(in_walls)), 1))
    finally:
        shutil.rmtree(root, ignore_errors=True)
