"""B3 — paper §VI: power model — core gating, static vs dynamic switching.

derived = energy ratio vs the ungated/static alternative.
"""
import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.power import PowerModel
from repro.core.scheduler import MBScheduler, TaskSpec


def run(csv_rows):
    profile = HeterogeneityProfile.paper()
    pm = PowerModel.cpu(profile)
    sched = MBScheduler(profile)

    # serial phase: best core + gating (paper function 3)
    asg = sched.assign_serial(TaskSpec("serial", 1000.0, parallel=False))
    busy = np.zeros(profile.n)
    busy[asg.serial_device] = asg.makespan
    e_gated = pm.energy(busy, asg.makespan, gated=asg.gated)
    e_idle = pm.energy(busy, asg.makespan, gated=[])
    csv_rows.append(("power_serial_gated_J", e_gated * 1e6, e_gated / e_idle))
    csv_rows.append(("power_serial_ungated_J", e_idle * 1e6, 1.0))

    # parallel phase energy: proportional vs equal (gating has nothing to
    # gate, but the shorter makespan cuts idle burn)
    costs = np.full(80, 10.0)
    task = TaskSpec("par", 800.0, parallel=True, n_tiles=80)
    for policy in ("equal", "proportional"):
        a = MBScheduler(profile, policy).assign_parallel(task, costs)
        e = pm.energy_of(a, costs, profile)
        csv_rows.append((f"power_parallel_{policy}_J", e * 1e6, a.makespan))

    # dynamic switching cost: energy charged per migration must stay below
    # the saving it buys (paper's constraint) — sweep migrations
    a = MBScheduler(profile, "proportional").assign_parallel(task, costs)
    base = pm.energy_of(a, costs, profile)
    for moves in (1, 10, 100):
        e = pm.energy_of(a, costs, profile, switches=moves)
        csv_rows.append((f"power_dynamic_{moves}moves_J", e * 1e6,
                         (e - base) / max(base, 1e-12)))
