"""B7 — the online serving plane: QPS vs batch size, cache on/off, and the
Pallas vs jitted-ref data plane, all on the paper's heterogeneous four-core
profile.

Emits ``name,us_per_call,derived`` CSV rows where us_per_call is host wall
microseconds per query and derived is the simulated QPS (the
policy-sensitive number; off-TPU the Pallas rows run in interpret mode, so
only the TPU run is a kernel speed claim — both rows verify the plumbing).
"""
import time

import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.data.baskets import BasketConfig, generate_baskets
from repro.pipeline import MarketBasketPipeline, PipelineConfig
from repro.serving import Query, RecommendationEngine, RuleIndex, ServingConfig


def _mine_index(n_items=64):
    T = generate_baskets(BasketConfig(n_tx=2048, n_items=n_items, seed=1))
    res = MarketBasketPipeline(
        HeterogeneityProfile.paper(),
        PipelineConfig(min_support=0.03, n_tiles=8)).run(T)
    return RuleIndex.build(res.rules, n_items)


def _trace(n_items=64, n_unique=128, repeats=4):
    """n_unique distinct baskets repeated `repeats` times: the repeated
    tail is what the result cache can win on."""
    Q = generate_baskets(BasketConfig(n_tx=n_unique, n_items=n_items, seed=7))
    return [Query.of(row) for row in Q] * repeats


def run(csv_rows):
    profile = HeterogeneityProfile.paper()
    index = _mine_index()
    queries = _trace()

    # QPS vs batch bucket, cache on/off (single-bucket engines so every
    # batch pads to exactly that size)
    for bucket in (1, 8, 64):
        for cache_size in (0, 4096):
            tag = "on" if cache_size else "off"
            engine = RecommendationEngine(
                index, profile,
                ServingConfig(k=5, batch_buckets=(bucket,),
                              data_plane="ref", cache_size=cache_size))
            engine.serve(queries[:8])            # warm the jit caches
            engine.cache.clear()
            t0 = time.perf_counter()
            _, rep = engine.serve(queries)
            wall_us = (time.perf_counter() - t0) * 1e6
            csv_rows.append((f"serving_b{bucket}_cache_{tag}",
                             wall_us / rep.n_queries, rep.qps))

    # autotuned Pallas vs jitted ref (interpret mode off-TPU).  The
    # baselines hold pallas *strictly faster* than ref, so warm both
    # engines, interleave the reps (drift hits both planes equally) and
    # report the median per-query wall
    small = queries[:64]
    engines, walls, qps = {}, {}, {}
    for plane in ("ref", "pallas"):
        engines[plane] = RecommendationEngine(
            index, profile,
            ServingConfig(k=5, batch_buckets=(8,), data_plane=plane,
                          cache_size=0))
        engines[plane].serve(small[:8])          # warm the jit caches
        walls[plane] = []
    for _ in range(5):
        for plane, engine in engines.items():
            t0 = time.perf_counter()
            _, rep = engine.serve(small)
            walls[plane].append((time.perf_counter() - t0) * 1e6
                                / rep.n_queries)
            qps[plane] = rep.qps
    for plane in ("ref", "pallas"):
        csv_rows.append((f"serving_plane_{plane}_wall",
                         float(np.median(walls[plane])), qps[plane]))

    # cache economics at the default bucket mix: hit rate as derived
    engine = RecommendationEngine(index, profile,
                                  ServingConfig(k=5, cache_size=4096,
                                                data_plane="ref"))
    engine.serve(queries[:8])                    # warm the jit caches
    engine.cache.clear()
    t0 = time.perf_counter()
    _, rep = engine.serve(queries)
    wall_us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("serving_cache_hit_rate", wall_us / rep.n_queries,
                     rep.hit_rate))
