"""B4 — hot-spot kernels: Pallas (interpret on CPU) vs jnp oracle µs/call,
plus the projected TPU-v5e roofline time for the same shape.

derived = oracle_us / kernel_us (CPU interpret — correctness-path timing),
and for *_roofline rows, the projected µs on TPU v5e.
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.support_count.ops import support_count
from repro.kernels.support_count.ref import support_count_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rwkv6_wkv.ref import wkv6_ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def _time(f, *args, reps=3):
    # synced warm-up: an unsynced one leaks async compile/dispatch time
    # into rep 0, and a mean over reps lets that one outlier set the row
    jax.block_until_ready(f(*args))
    walls = []
    for _ in range(max(reps, 3)):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls)) * 1e6


def run(csv_rows):
    rng = np.random.default_rng(0)

    # support_count: N=4096 tx, I=256 items, M=512 candidates
    N, I, M = 4096, 256, 512
    T = jnp.asarray((rng.random((N, I)) < 0.3).astype(np.uint8))
    C = np.zeros((M, I), np.uint8)
    for m in range(M):
        C[m, rng.choice(I, 3, replace=False)] = 1
    C = jnp.asarray(C)
    t_ref = _time(jax.jit(support_count_ref), T, C)
    # the historical mxu row (variant pinned) vs the autotuned path
    # (checked-in cache -> fused packed-popcount on cpu)
    mxu = {"variant": "mxu", "bn": 512, "bm": 256, "bi": 256}
    t_pal = _time(lambda a, b: support_count(a, b, tuning=mxu), T, C)
    t_fused = _time(lambda a, b: support_count(a, b), T, C)
    csv_rows.append(("support_count_ref_us", t_ref, 1.0))
    csv_rows.append(("support_count_pallas_interp_us", t_pal, t_ref / t_pal))
    csv_rows.append(("support_count_fused_interp_us", t_fused,
                     t_ref / t_fused))
    flops = 2.0 * N * I * M
    t_tpu = max(flops / PEAK_FLOPS, (N * I + M * I + M * 4) / HBM_BW) * 1e6
    csv_rows.append(("support_count_tpu_roofline_us", t_tpu, flops / 1e9))

    # flash attention fwd: B1 S1024 H8 hd64 (oracle timing + roofline)
    B, S, H, hd = 1, 1024, 8, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.bfloat16)
    t_ref = _time(jax.jit(lambda q: flash_attention_ref(q, q, q)), q)
    csv_rows.append(("flash_attn_ref_us", t_ref, 1.0))
    flops = 4.0 * B * H * S * S * hd
    bytes_flash = 4 * B * S * H * hd * 2
    csv_rows.append(("flash_attn_tpu_roofline_us",
                     max(flops / PEAK_FLOPS, bytes_flash / HBM_BW) * 1e6,
                     flops / 1e9))

    # wkv6: B1 T512 H4 n64
    Bw, Tw, Hw, n = 1, 512, 4, 64
    r = jnp.asarray(rng.standard_normal((Bw, Tw, Hw, n)) * 0.5, jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((Bw, Tw, Hw, n)))), jnp.float32)
    u = jnp.asarray(rng.standard_normal((Hw, n)), jnp.float32)
    t_ref = _time(jax.jit(lambda r, w, u: wkv6_ref(r, r, r, w, u)[0]), r, w, u)
    csv_rows.append(("wkv6_ref_scan_us", t_ref, 1.0))
    flops = Bw * Tw * Hw * (4 * n * n)
    state_bytes = Bw * Tw * Hw * n * 4 * 4
    csv_rows.append(("wkv6_tpu_roofline_us",
                     max(flops / PEAK_FLOPS, state_bytes / HBM_BW) * 1e6,
                     flops / 1e9))
