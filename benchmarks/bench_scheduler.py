"""B2 — paper §V/§VI: MB Scheduler vs naive equal split on the paper's
80/120/200/400 four-core system (and pod-scale straggler profiles).

derived = speedup over equal split.
"""
import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.scheduler import MBScheduler, TaskSpec


def _makespan(profile, policy, costs):
    t = TaskSpec("t", float(costs.sum()), parallel=True, n_tiles=len(costs))
    return MBScheduler(profile, policy).assign_parallel(t, costs).makespan


def run(csv_rows):
    rng = np.random.default_rng(0)
    scenarios = {
        "paper4core": (HeterogeneityProfile.paper(), np.full(80, 10.0)),
        "paper4core_skewed": (HeterogeneityProfile.paper(),
                              rng.zipf(1.6, 80).astype(float)),
        "pod_straggler8": (HeterogeneityProfile.straggler(8, 1, 4.0),
                           np.full(64, 10.0)),
        "pod_straggler256": (HeterogeneityProfile.straggler(256, 8, 3.0),
                             np.full(2048, 10.0)),
        "mixed_gen": (HeterogeneityProfile.mixed_generation(128, 128, 2.35),
                      np.full(2048, 10.0)),
    }
    for name, (profile, costs) in scenarios.items():
        m_eq = _makespan(profile, "equal", costs)
        m_prop = _makespan(profile, "proportional", costs)
        m_lpt = _makespan(profile, "lpt", costs)
        csv_rows.append((f"sched_{name}_equal_us", m_eq * 1e6, 1.0))
        csv_rows.append((f"sched_{name}_proportional_us", m_prop * 1e6,
                         m_eq / m_prop))
        csv_rows.append((f"sched_{name}_lpt_us", m_lpt * 1e6, m_eq / m_lpt))
