"""B9 — switching-policy comparison (paper §VI: static vs dynamic).

A skewed-tile workload runs for several phases on a 4-core system whose
*believed* speeds start uniform while one core truly runs 4x slower (an
injected straggler — the multi-tenant / thermal-throttle case).  Phase
walls are measured under the true rates; only DynamicPolicy feeds them
back (EWMA) and speculates on stragglers, so:

  static     keeps planning from the stale speeds — every phase pays the
             straggler's full share
  dynamic    corrects the believed speeds after the first measurement and
             re-issues straggler tails — makespan collapses toward the
             heterogeneity-aware optimum
  costmodel  static planning over roofline-seeded costs (compute-bound
             tiles weighted by flops, not bytes)

Rows (modeled seconds -> us, deterministic, so the 2.0x regression gate is
noise-free):
  policies_{static,dynamic,costmodel}_makespan_us   derived = energy J
  policies_dynamic_speedup                          derived = static/dynamic

Gate: dynamic must beat static under the injected straggler — a regression
here means the closed loop stopped closing.
"""
import numpy as np

from repro.core.hetero import HeterogeneityProfile
from repro.core.scheduler import TaskSpec
from repro.runtime import CostModelPolicy, MeasuredPhase, Runtime

N_PHASES = 6
TRUE_SPEEDS = np.array([25.0, 100.0, 100.0, 100.0])   # core 0 straggles


def _workload():
    rng = np.random.default_rng(0)
    costs = rng.zipf(1.6, 96).astype(np.float64) * 64.0   # skewed tiles
    # a third of the tiles are compute-bound (for the costmodel row)
    flops = costs * 2e3
    flops[::3] *= 50.0
    return costs, flops


def _run_policy(policy, costs, flops):
    believed = HeterogeneityProfile(np.full(4, 100.0))
    rt = Runtime(believed, policy=policy, split="lpt", power="cpu")

    def execute(asg, _seeded):
        # walls reflect the *real* byte work under the true rates — the
        # policy only ever controlled placement, not physics
        load = np.array([costs[ts].sum() if ts else 0.0
                         for ts in asg.tiles_of])
        busy = load / TRUE_SPEEDS
        return MeasuredPhase(busy_s=busy, makespan=float(busy.max()),
                             work_done=load)

    total_s = 0.0
    for _ in range(N_PHASES):
        task = TaskSpec("b9-phase", float(costs.sum()), parallel=True,
                        n_tiles=len(costs))
        _, rec = rt.run_phase(task, execute, tile_costs=costs,
                              tile_flops=flops)
        total_s += rec.sim_time_s
    return total_s, rt.ledger.total_energy_j


def run(csv_rows):
    costs, flops = _workload()
    totals = {}
    for name in ("static", "dynamic", "costmodel"):
        policy = (CostModelPolicy(peak_flops=1e8, hbm_bw=1e6)
                  if name == "costmodel" else name)
        total_s, energy = _run_policy(policy, costs, flops)
        totals[name] = total_s
        csv_rows.append((f"policies_{name}_makespan_us", total_s * 1e6,
                         energy))
    speedup = totals["static"] / totals["dynamic"]
    csv_rows.append(("policies_dynamic_speedup", 0.0, speedup))
    if totals["dynamic"] >= totals["static"]:
        raise AssertionError(
            f"dynamic ({totals['dynamic']:.3f}s) must beat static "
            f"({totals['static']:.3f}s) under an injected straggler")
